#!/usr/bin/env python
"""Documentation gate (CI docs job).

Two checks over ``README.md`` and ``docs/*.md``:

1. **Relative links resolve** — every ``[text](target)`` markdown link
   that is not an absolute URL or a pure in-page anchor must point at an
   existing file/directory, resolved against the linking file's location
   (URL fragments are stripped first).
2. **Doctests pass** — any file containing ``>>>`` examples is run
   through :mod:`doctest` (``src/`` is prepended to ``sys.path``, so the
   examples import the package exactly like the test suite does).

Exit status is nonzero on any broken link or failing example:

    python tools/check_docs.py
"""
from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(1, str(REPO))           # README examples import benchmarks.*

# [text](target) — excludes images' leading "!" capture on purpose: image
# targets must resolve too, and the regex matches them the same way
_LINK = re.compile(r"\[[^\]\[]*\]\(([^)\s]+)\)")


def doc_files() -> list[Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_links(path: Path) -> list[str]:
    errors = []
    text = path.read_text()
    for m in _LINK.finditer(text):
        target = m.group(1)
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):    # http:, mailto:, ...
            continue
        if target.startswith("#"):                      # in-page anchor
            continue
        rel = target.split("#", 1)[0]
        if not (path.parent / rel).exists():
            errors.append(f"{path.relative_to(REPO)}: broken link -> {target}")
    return errors


def run_doctests(path: Path) -> list[str]:
    if ">>>" not in path.read_text():
        return []
    failures, tests = doctest.testfile(
        str(path), module_relative=False, verbose=False,
        optionflags=doctest.NORMALIZE_WHITESPACE | doctest.ELLIPSIS)
    print(f"{path.relative_to(REPO)}: {tests} doctest examples, "
          f"{failures} failures")
    if failures:
        return [f"{path.relative_to(REPO)}: {failures} doctest failures"]
    return []


def main() -> int:
    errors = []
    for f in doc_files():
        errors += check_links(f)
    for f in doc_files():
        errors += run_doctests(f)
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if not errors:
        print(f"docs OK: {len(doc_files())} files checked")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
