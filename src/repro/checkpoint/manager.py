"""Sharded checkpointing with atomic commits and elastic restore.

Layout:  <dir>/step_<N>/
            index.json          tree structure, shapes, dtypes, step, extras
            leaf_<i>.npy        one file per pytree leaf

Writes go to ``step_<N>.tmp`` and are atomically renamed, so a crash mid-
save never corrupts the latest checkpoint (restart safety).  Restore takes
an optional sharding tree and ``jax.device_put``s each leaf — loading onto
a *different* mesh shape than the one that saved it (elastic re-shard) is
therefore free.  ``keep`` bounds disk usage.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Pytree = Any


def _flatten(tree: Pytree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Pytree, *,
         extras: Optional[Dict] = None, keep: int = 3) -> str:
    base = Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    meta = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "extras": extras or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"leaf_{i}.npy", arr)
        meta["leaves"].append({"shape": list(arr.shape),
                               "dtype": str(arr.dtype)})
    (tmp / "index.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit

    # retention
    ckpts = sorted(p for p in base.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old)
    return str(final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = Path(ckpt_dir)
    if not base.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in base.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Pytree, *, step: Optional[int] = None,
            shardings: Optional[Pytree] = None) -> Tuple[Pytree, int, Dict]:
    """Restore into the structure of ``template`` (shapes must match).

    ``shardings`` (same structure) re-shards each leaf onto the current
    mesh — elastic restore across topologies.
    """
    step = latest_step(ckpt_dir) if step is None else step
    assert step is not None, f"no checkpoint under {ckpt_dir}"
    d = Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((d / "index.json").read_text())

    leaves, treedef = _flatten(template)
    assert len(leaves) == meta["n_leaves"], "tree structure changed"
    sh_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                 else [None] * len(leaves))
    out = []
    for i, (tmpl, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = np.load(d / f"leaf_{i}.npy")
        expect = tuple(getattr(tmpl, "shape", arr.shape))
        assert tuple(arr.shape) == expect, (i, arr.shape, expect)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return treedef.unflatten(out), step, meta["extras"]
