"""Architecture registry: ``--arch <id>`` resolves here.

Ten assigned architectures (each paired with the four LM shapes) plus the
paper's own benchmark-suite configs (see ``repro.configs.paper_suite``).
"""
from __future__ import annotations

from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    SHAPES_BY_NAME,
    TrainConfig,
    MeshConfig,
)

from repro.configs.whisper_medium import CONFIG as WHISPER_MEDIUM
from repro.configs.minicpm3_4b import CONFIG as MINICPM3_4B
from repro.configs.qwen15_110b import CONFIG as QWEN15_110B
from repro.configs.qwen3_8b import CONFIG as QWEN3_8B
from repro.configs.qwen15_4b import CONFIG as QWEN15_4B
from repro.configs.llama4_maverick_400b import CONFIG as LLAMA4_MAVERICK
from repro.configs.qwen3_moe_235b import CONFIG as QWEN3_MOE_235B
from repro.configs.recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B
from repro.configs.qwen2_vl_72b import CONFIG as QWEN2_VL_72B
from repro.configs.mamba2_370m import CONFIG as MAMBA2_370M

ARCHS = {
    c.name: c
    for c in (
        WHISPER_MEDIUM,
        MINICPM3_4B,
        QWEN15_110B,
        QWEN3_8B,
        QWEN15_4B,
        LLAMA4_MAVERICK,
        QWEN3_MOE_235B,
        RECURRENTGEMMA_2B,
        QWEN2_VL_72B,
        MAMBA2_370M,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cells():
    """All (arch, shape) dry-run cells, with applicability flags."""
    out = []
    for arch in ARCHS.values():
        for shape in SHAPES:
            skip = None
            if shape.name == "long_500k" and not arch.subquadratic:
                skip = "full attention (quadratic) — skipped per assignment rules"
            out.append((arch, shape, skip))
    return out


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "SHAPES_BY_NAME", "TrainConfig",
    "MeshConfig", "ARCHS", "get_arch", "cells",
]
