"""The paper's own Table I DNNs as first-class ModelConfigs (the LM ones)
plus pointers to the vision implementations — so the paper's baseline suite
is runnable through the same train/serve/dry-run machinery as the assigned
architectures.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig

# BERT-Base (Conversational Chatbot, Table I): encoder-style usage is
# emulated with bidirectional = non-causal prefill.
BERT_BASE = ModelConfig(
    name="bert-base",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=30522,
    head_dim=64,
    rope="learned",
    act="gelu",
    max_position=512,
    tie_embeddings=True,
)

# GPT-2 XL-and-a-half (Document Translation, Table I: "GPT-2 (1.5 billion)")
GPT2_1_5B = ModelConfig(
    name="gpt2-1.5b",
    family="dense",
    num_layers=48,
    d_model=1600,
    num_heads=25,
    num_kv_heads=25,
    d_ff=6400,
    vocab_size=50257,
    head_dim=64,
    rope="learned",
    act="gelu",
    max_position=1024,
    tie_embeddings=True,
)

# ViT-H-class backbone (Remote Sensing, Table I: "Vision Transformer 632M")
VIT_632M = ModelConfig(
    name="vit-632m",
    family="vlm",
    num_layers=32,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=1000,          # classification head
    head_dim=80,
    rope="learned",
    act="gelu",
    frontend="vision_patches",
    frontend_seq=256,
    max_position=1024,
    tie_embeddings=False,
)

PAPER_LM_SUITE = {c.name: c for c in (BERT_BASE, GPT2_1_5B, VIT_632M)}

# Vision/CNN members of Table I live in repro.models.vision
# (resnet50/effnet/fcn/yolov3) and repro.core.workloads carries the full
# 8-benchmark system-level suite.
