"""minicpm3-4b [dense]: 62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448 — MLA.

Multi-head latent attention (DeepSeek-V2 style) with the MiniCPM3 projection
ranks.  [hf:openbmb/MiniCPM3-4B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    rope_head_dim=32,
    nope_head_dim=64,
    v_head_dim=64,
    head_dim=96,   # nope + rope
    rope="rope",
)
