"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

M-RoPE (3-section t/h/w rotary), dynamic resolution.  Vision patch frontend
STUB: ``input_specs`` provides precomputed patch embeddings.  [arXiv:2409.12191]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope="mrope",
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    frontend_seq=1024,
    tie_embeddings=False,
)
