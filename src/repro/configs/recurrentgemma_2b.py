"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, 1:2 ratio (pattern R,R,A).

Sub-quadratic: runs long_500k.  [arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "attn"),
    sliding_window=2048,
    rope="rope",
    act="gelu",
    tie_embeddings=True,
)
