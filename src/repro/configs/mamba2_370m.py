"""mamba2-370m [ssm]: 48L d_model=1024 (attn-free) vocab=50280, ssm_state=128.

SSD (state-space duality), chunked.  Sub-quadratic: runs long_500k.
[arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=32,          # ssm heads = expand*d_model / ssm_head_dim
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    rope="none",
    block_pattern=("ssd",),
    ssm_state=128,
    ssm_chunk=256,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
)
