"""Model / run configuration dataclasses.

One ``ModelConfig`` covers every assigned architecture family (dense, MoE,
hybrid RG-LRU, SSM, VLM, audio enc-dec) plus the paper's own benchmark
models.  Configs are pure data: the model code in ``repro.models`` consumes
them, the launcher maps them onto meshes, and the smoke tests instantiate
``reduced()`` variants.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 => d_model // num_heads

    # --- attention flavour -------------------------------------------------
    attention: str = "gqa"            # gqa | mla | none
    qk_norm: bool = False
    qkv_bias: bool = False
    rope: str = "rope"                # rope | mrope | learned | none
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # >0 => local attention window
    # repeating block pattern; entries: "attn" | "rglru"
    block_pattern: Tuple[str, ...] = ("attn",)

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                 # per-expert FFN width (0 => d_ff)
    moe_capacity_factor: float = 1.25
    moe_block_tokens: int = 8192      # scan MoE dispatch in token blocks (0 = off)
    moe_impl: str = "ep"              # gather | ep | ep_resident (see moe_ep.py)

    # --- MLA (multi-head latent attention; MiniCPM3/DeepSeek style) ---------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64

    # --- encoder-decoder ------------------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0              # whisper: 1500 precomputed frames
    cross_attention: bool = False

    # --- modality frontend (STUB: input_specs feeds precomputed embeddings) ---
    frontend: str = "none"            # none | audio_frames | vision_patches
    frontend_seq: int = 0             # length of precomputed frontend embeds

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"                 # silu | gelu

    # --- numerics & lowering knobs -------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 512             # q-chunk for blocked attention
    attn_unroll: bool = True          # unroll the q-chunk loop (exact HLO flops)
    max_position: int = 1 << 20

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the vocab dim shards on any
        production mesh axis (Megatron-style embedding padding).  Logit
        columns >= vocab_size are masked to -inf in ``unembed``."""
        if self.vocab_size % 256 == 0:
            return self.vocab_size
        return ((self.vocab_size + 255) // 256) * 256

    # sub-quadratic? (controls long_500k applicability)
    @property
    def subquadratic(self) -> bool:
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            # attention blocks must all be windowed
            return self.sliding_window > 0
        return False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoder-bearing (none encoder-only)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        period = len(self.block_pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=max(period, 2 if period == 1 else period),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads > 1 else 1,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            num_experts=min(self.num_experts, 8) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.experts_per_token else 0,
            moe_d_ff=64 if self.num_experts else 0,
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            rope_head_dim=8 if self.rope_head_dim else 0,
            nope_head_dim=24 if self.nope_head_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_chunk=32,
            ssm_head_dim=32 if self.ssm_state else 64,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16 if self.encoder_seq else 0,
            frontend_seq=16 if self.frontend_seq else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            attn_chunk=32,
            max_position=4096,
            dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned input-shape cell."""
    name: str                         # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                         # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class TrainConfig:
    """End-to-end training-run configuration (launcher + optimizer)."""
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 300
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    microbatches: int = 1             # gradient accumulation
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    grad_compression: str = "none"    # none | int8  (DP all-reduce compression)


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n
