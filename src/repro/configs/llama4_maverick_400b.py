"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1.  Early fusion (vision frontend STUB).

[hf:meta-llama/Llama-4-Scout-17B-16E family]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    num_experts=128,
    experts_per_token=1,
    moe_d_ff=8192,
    rope="rope",
    rope_theta=500_000.0,
    tie_embeddings=False,
)
