"""whisper-medium [audio]: 24L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865.

Encoder-decoder with a conv audio frontend (STUB: ``input_specs`` provides
1500 precomputed frame embeddings).  [arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    attention="gqa",
    rope="learned",
    act="gelu",
    encoder_layers=24,
    encoder_seq=1500,
    cross_attention=True,
    frontend="audio_frames",
    frontend_seq=1500,
    tie_embeddings=True,
    max_position=65536,
)
