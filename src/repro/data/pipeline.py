"""Deterministic, resumable synthetic data pipeline.

Batches are a pure function of (seed, step), so restoring a checkpoint and
replaying from its step reproduces the exact stream — the property the
fault-tolerance test asserts.  Host-side numpy generation, device_put with
the batch sharding (the sharded-host-loading pattern).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class TokenStream:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    shardings: Optional[Dict[str, Any]] = None

    def batch_at(self, step: int) -> Dict[str, Any]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        # zipf-ish token distribution (more realistic than uniform)
        ranks = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = np.minimum(ranks, self.cfg.vocab_size - 1).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.frontend == "audio_frames":
            out["encoder_frames"] = rng.normal(
                0, 0.02, (self.batch, self.cfg.encoder_seq, self.cfg.d_model)
            ).astype(np.float32)
        if self.cfg.frontend == "vision_patches":
            out["frontend_embeds"] = rng.normal(
                0, 0.02, (self.batch, self.cfg.frontend_seq, self.cfg.d_model)
            ).astype(np.float32)
        if self.shardings:
            out = {k: jax.device_put(v, self.shardings.get(k))
                   for k, v in out.items()}
        return out

    def iter_from(self, step: int) -> Iterator[Dict[str, Any]]:
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class RequestStream:
    """Poisson request arrivals for the serving driver."""
    cfg: ModelConfig
    batch: int
    prompt_len: int
    seed: int = 0

    def requests_at(self, step: int) -> Dict[str, Any]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        toks = rng.integers(0, self.cfg.vocab_size,
                            (self.batch, self.prompt_len)).astype(np.int32)
        return {"tokens": toks}
