"""Serving path: cache construction, prefill, and single-token decode.

Cache layout mirrors the param layout: scanned groups hold stacked leaves
(G, ...) consumed by ``lax.scan`` during decode; pattern remainders are
per-layer dicts.  Per-family caches:

  attn   : full K/V (B, S, KV, Dh) written at ``pos``  (decode_32k)
  attn+sw: ring buffer (B, W, KV, Dh) + slot->position map (W,)  (long_500k)
  mla    : compressed latent (B, S, r) + shared rope keys (B, S, dr);
           decode uses the *absorbed* formulation (scores in latent space)
  rglru  : recurrent state (B, W) fp32 + conv tail (B, K-1, W)
  ssd    : SSM state (B, H, P, N) fp32 + conv tail
  cross  : encoder K/V computed once at prefill (whisper)

``pos`` is a shared scalar (all sequences advance in lock-step), which is
what the dry-run cells specify (a KV cache of exactly seq_len).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

Pytree = Any


# ---------------------------------------------------------------------------
# cache shape definitions
# ---------------------------------------------------------------------------

def _use_ring(cfg: ModelConfig, seq: int) -> bool:
    return cfg.sliding_window > 0 and seq > cfg.sliding_window


def layer_cache_def(cfg: ModelConfig, kind: str, batch: int, seq: int,
                    decoder: bool = True) -> Dict[str, jax.ShapeDtypeStruct]:
    dt = jnp.dtype(cfg.dtype)
    Dh = cfg.resolved_head_dim
    KV = cfg.num_kv_heads
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if kind == "attn":
        if cfg.attention == "mla":
            out["lat"] = jax.ShapeDtypeStruct((batch, seq, cfg.kv_lora_rank), dt)
            out["kr"] = jax.ShapeDtypeStruct((batch, seq, cfg.rope_head_dim), dt)
        elif _use_ring(cfg, seq):
            W = cfg.sliding_window
            out["k"] = jax.ShapeDtypeStruct((batch, W, KV, Dh), dt)
            out["v"] = jax.ShapeDtypeStruct((batch, W, KV, Dh), dt)
            out["kpos"] = jax.ShapeDtypeStruct((W,), jnp.int32)
        else:
            out["k"] = jax.ShapeDtypeStruct((batch, seq, KV, Dh), dt)
            out["v"] = jax.ShapeDtypeStruct((batch, seq, KV, Dh), dt)
    elif kind == "rglru":
        W = cfg.d_model
        out["h"] = jax.ShapeDtypeStruct((batch, W), jnp.float32)
        out["conv"] = jax.ShapeDtypeStruct((batch, 3, W), dt)
    elif kind == "ssd":
        din = cfg.ssm_expand * cfg.d_model
        H = din // cfg.ssm_head_dim
        conv_ch = din + 2 * cfg.ssm_ngroups * cfg.ssm_state
        out["h"] = jax.ShapeDtypeStruct(
            (batch, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        out["conv"] = jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_ch), dt)
    if decoder and cfg.cross_attention:
        out["xk"] = jax.ShapeDtypeStruct((batch, cfg.encoder_seq, KV, Dh), dt)
        out["xv"] = jax.ShapeDtypeStruct((batch, cfg.encoder_seq, KV, Dh), dt)
    return out


def cache_shapes(cfg: ModelConfig, batch: int, seq: int) -> Pytree:
    """ShapeDtypeStruct cache tree (dry-run: no allocation)."""
    period = len(cfg.block_pattern)
    groups, rem = divmod(cfg.num_layers, period)
    group_tree = {
        f"b{j}_{kind}": layer_cache_def(cfg, kind, batch, seq)
        for j, kind in enumerate(cfg.block_pattern)
    }
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((groups,) + s.shape, s.dtype), group_tree
    ) if groups else {}
    return {
        "blocks": stacked,
        "rem": [layer_cache_def(cfg, cfg.block_pattern[j % period], batch, seq)
                for j in range(rem)],
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def layer_cache_axes(cfg: ModelConfig, kind: str, batch: int, seq: int,
                     decoder: bool = True) -> Dict[str, tuple]:
    """Logical sharding axes mirroring ``layer_cache_def`` leaf-for-leaf."""
    out: Dict[str, tuple] = {}
    if kind == "attn":
        if cfg.attention == "mla":
            out["lat"] = ("cache_batch", "cache_seq", None)
            out["kr"] = ("cache_batch", "cache_seq", None)
        elif _use_ring(cfg, seq):
            out["k"] = ("cache_batch", "cache_seq", None, None)  # ring W/model
            out["v"] = ("cache_batch", "cache_seq", None, None)
            out["kpos"] = (None,)
        else:
            out["k"] = ("cache_batch", "cache_seq", None, None)
            out["v"] = ("cache_batch", "cache_seq", None, None)
    elif kind == "rglru":
        out["h"] = ("cache_batch", None)
        out["conv"] = ("cache_batch", None, None)
    elif kind == "ssd":
        out["h"] = ("cache_batch", "heads", None, None)
        out["conv"] = ("cache_batch", None, None)
    if decoder and cfg.cross_attention:
        out["xk"] = ("cache_batch", "cache_seq", None, None)
        out["xv"] = ("cache_batch", "cache_seq", None, None)
    return out


def cache_logical_axes(cfg: ModelConfig, batch: int, seq: int) -> Pytree:
    period = len(cfg.block_pattern)
    groups, rem = divmod(cfg.num_layers, period)
    group_tree = {
        f"b{j}_{kind}": layer_cache_axes(cfg, kind, batch, seq)
        for j, kind in enumerate(cfg.block_pattern)
    }
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    stacked = jax.tree.map(lambda ax: ("layer",) + ax, group_tree,
                           is_leaf=is_ax) if groups else {}
    return {
        "blocks": stacked,
        "rem": [layer_cache_axes(cfg, cfg.block_pattern[j % period], batch, seq)
                for j in range(rem)],
        "pos": (None,),   # scalar; zip-trimmed to P()
    }


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> Pytree:
    def mk(s: jax.ShapeDtypeStruct):
        if s.dtype == jnp.int32 and s.shape and len(s.shape) == 1:
            return jnp.full(s.shape, -1, jnp.int32)    # ring kpos
        return jnp.zeros(s.shape, s.dtype)
    tree = jax.tree.map(mk, cache_shapes(cfg, batch, seq))
    tree["pos"] = jnp.zeros((), jnp.int32)
    return tree


# ---------------------------------------------------------------------------
# single-token block steps
# ---------------------------------------------------------------------------

def _ring_attend(q, kc, vc, kpos, pos, window):
    """q (B,1,H,Dh) vs ring cache (B,W,KV,Dh); kpos (W,) slot->abs position."""
    B, _, H, Dh = q.shape
    KV = kc.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, Dh)
    s = jnp.einsum("bckgd,bskd->bkgcs", qg, kc).astype(jnp.float32) / math.sqrt(Dh)
    ok = (kpos >= 0) & (kpos <= pos) & ((pos - kpos) < window)
    s = jnp.where(ok[None, None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgcs,bskd->bckgd", w.astype(vc.dtype), vc)
    return o.reshape(B, 1, H, vc.shape[-1])


def attn_step(cfg: ModelConfig, p, x, cache, pos, ctx):
    Dh = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    q = T._heads(T._proj(h, p["wq"], p.get("bq")), H, Dh)
    k = T._heads(T._proj(h, p["wk"], p.get("bk")), KV, Dh)
    v = T._heads(T._proj(h, p["wv"], p.get("bv")), KV, Dh)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["qn"], cfg.norm_eps)
        k = L.rms_norm(k, p["kn"], cfg.norm_eps)
    if cfg.rope in ("rope", "mrope"):
        q = L.apply_rope(q, ctx.cos, ctx.sin)
        k = L.apply_rope(k, ctx.cos, ctx.sin)
    window = cfg.sliding_window if cfg.family == "hybrid" else 0
    if "kpos" in cache:                       # ring buffer (long-context local)
        W = cfg.sliding_window
        slot = pos % W
        kc = lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        vc = lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        kpos = lax.dynamic_update_slice(cache["kpos"], pos[None], (slot,))
        o = _ring_attend(q, kc, vc, kpos, pos, W)
        new_cache = dict(cache, k=kc, v=vc, kpos=kpos)
    else:
        kc = lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        vc = lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        o = L._attn_block(q, kc, vc, q_start=pos, kv_start=0, causal=True,
                          window=window, kv_len=pos + 1)
        new_cache = dict(cache, k=kc, v=vc)
    x = x + T._proj(o.reshape(x.shape[0], 1, H * Dh), p["wo"])
    return x, new_cache


def mla_step(cfg: ModelConfig, p, x, cache, pos, ctx):
    """Absorbed MLA decode: scores and context in latent space."""
    H = cfg.num_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    B = x.shape[0]
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    cq = L.rms_norm(T._proj(h, p["wq_a"]), p["q_ln"], cfg.norm_eps)
    q = T._heads(T._proj(cq, p["wq_b"]), H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, ctx.cos_r, ctx.sin_r)
    kv = T._proj(h, p["wkv_a"])
    lat_t = L.rms_norm(kv[..., :r], p["kv_ln"], cfg.norm_eps)    # (B,1,r)
    kr_t = L.apply_rope(kv[..., r:][:, :, None, :], ctx.cos_r, ctx.sin_r)[:, :, 0]
    lat = lax.dynamic_update_slice(cache["lat"], lat_t, (0, pos, 0))
    kr = lax.dynamic_update_slice(cache["kr"], kr_t, (0, pos, 0))
    wk = p["wk_b"].reshape(r, H, dn)
    wv = p["wv_b"].reshape(r, H, dv)
    # absorb wk into q:  q_lat (B,1,H,r)
    q_lat = jnp.einsum("bchn,rhn->bchr", q_nope, wk.astype(q_nope.dtype))
    s = (jnp.einsum("bchr,bsr->bhcs", q_lat, lat)
         + jnp.einsum("bchp,bsp->bhcs", q_rope, kr)).astype(jnp.float32)
    s = s / math.sqrt(dn + dr)
    valid = jnp.arange(lat.shape[1]) <= pos
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhcs,bsr->bchr", w.astype(lat.dtype), lat)
    o = jnp.einsum("bchr,rhv->bchv", ctx_lat, wv.astype(ctx_lat.dtype))
    x = x + T._proj(o.reshape(B, 1, H * dv), p["wo"])
    return x, dict(cache, lat=lat, kr=kr)


def cross_step(cfg: ModelConfig, p, x, cache, ctx):
    Dh = cfg.resolved_head_dim
    H = cfg.num_heads
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    q = T._heads(T._proj(h, p["wq"]), H, Dh)
    o = L._attn_block(q, cache["xk"], cache["xv"], q_start=0, kv_start=0,
                      causal=False, window=0, kv_len=None)
    return x + T._proj(o.reshape(x.shape[0], 1, H * Dh), p["wo"])


def rglru_step_block(cfg: ModelConfig, p, x, cache, ctx):
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    gate = L.act_fn("gelu")(T._proj(h, p["wy"]))[:, 0]
    xb_t = T._proj(h, p["wx"])[:, 0]                            # (B,W)
    hist = jnp.concatenate([cache["conv"].astype(x.dtype), xb_t[:, None]], axis=1)
    w = p["conv_w"]
    conv = sum(hist[:, i] * w[i][None, :] for i in range(w.shape[0]))
    ga = conv @ p["wga"].astype(x.dtype) + p["bga"].astype(x.dtype)
    gx = conv @ p["wgx"].astype(x.dtype) + p["bgx"].astype(x.dtype)
    hn = L.rglru_step(conv, gx, ga, p["log_a"], cache["h"])
    y = T._proj((hn.astype(x.dtype) * gate)[:, None], p["wo"])
    return x + y, dict(cache, h=hn.astype(jnp.float32), conv=hist[:, 1:])


def ssd_step_block(cfg: ModelConfig, p, x, cache, ctx):
    D = cfg.d_model
    din = cfg.ssm_expand * D
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    H = din // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = T._proj(h, p["in_proj"])[:, 0]                     # (B, ...)
    z, xs, BC, dt = jnp.split(zxbcdt, [din, 2 * din, 2 * din + 2 * G * N], axis=-1)
    conv_in = jnp.concatenate([xs, BC], axis=-1)
    hist = jnp.concatenate([cache["conv"].astype(x.dtype), conv_in[:, None]], axis=1)
    w = p["conv_w"]
    conv = jax.nn.silu(sum(hist[:, i] * w[i][None, :] for i in range(w.shape[0])))
    xs, Bm, Cm = jnp.split(conv, [din, din + G * N], axis=-1)
    xt = xs.reshape(-1, H, P)
    Bt = Bm.reshape(-1, G, N)
    Ct = Cm.reshape(-1, G, N)
    dtt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, hn = L.ssd_step(xt, dtt, A, Bt, Ct, cache["h"])
    y = y + xt * p["d_skip"].astype(x.dtype)[None, :, None]
    y = L.rms_norm(y.reshape(-1, din) * jax.nn.silu(z), p["out_ln"], cfg.norm_eps)
    out = T._proj(y[:, None], p["out_proj"])
    return x + out, dict(cache, h=hn, conv=hist[:, 1:])


def block_step(cfg: ModelConfig, kind: str, p, x, cache, pos, ctx):
    if kind == "attn":
        if cfg.attention == "mla":
            x, cache = mla_step(cfg, p["attn"], x, cache, pos, ctx)
        else:
            x, cache = attn_step(cfg, p["attn"], x, cache, pos, ctx)
    elif kind == "rglru":
        x, c2 = rglru_step_block(cfg, p["rec"], x,
                                 {"h": cache["h"], "conv": cache["conv"]}, ctx)
        cache = dict(cache, **c2)
    elif kind == "ssd":
        x, c2 = ssd_step_block(cfg, p["ssd"], x,
                               {"h": cache["h"], "conv": cache["conv"]}, ctx)
        cache = dict(cache, **c2)
    if "xattn" in p and "xk" in cache:
        x = cross_step(cfg, p["xattn"], x, cache, ctx)
    if "ffn" in p:
        x = T.ffn_forward(cfg, p["ffn"], x, ctx)
    return ctx.shard(x, "act"), cache


# ---------------------------------------------------------------------------
# decode step (one new token for the whole batch)
# ---------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params, cache, tokens, *,
                shard=lambda x, k: x) -> Tuple[jax.Array, Pytree]:
    """tokens (B, 1) at position cache['pos'] -> (logits (B,1,V), new cache)."""
    pos = cache["pos"]
    B = tokens.shape[0]
    x = T.embed_tokens(cfg, params, tokens)
    if cfg.rope == "learned":
        x = x + params["pos_embed"][pos[None]].astype(x.dtype)[None]
    x = shard(x, "act")

    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(positions[..., None], (B, 1, 3))
    ctx = T.Ctx(cfg=cfg, shard=shard, q_offset=pos, kv_len=pos + 1)
    if cfg.rope in ("rope", "mrope"):
        ctx.cos, ctx.sin = T._rope_ctx(cfg, positions, cfg.resolved_head_dim)
        if cfg.attention == "mla":
            ctx.cos_r, ctx.sin_r = T._rope_ctx(cfg, positions, cfg.rope_head_dim)
            ctx.cos = ctx.sin = None

    pattern = cfg.block_pattern

    def group_step(xc, gpc):
        gp, gc = gpc
        new_gc = {}
        for j, kind in enumerate(pattern):
            key = f"b{j}_{kind}"
            xc, new_gc[key] = block_step(cfg, kind, gp[key], xc, gc[key], pos, ctx)
        return xc, new_gc

    new_cache: Dict[str, Any] = {"pos": pos + 1}
    if cache["blocks"]:
        x, new_blocks = lax.scan(group_step, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = new_blocks
    else:
        new_cache["blocks"] = {}
    new_rem = []
    for j, (lp, lc) in enumerate(zip(params["rem"], cache["rem"])):
        kind = pattern[j % len(pattern)]
        x, nc = block_step(cfg, kind, lp, x, lc, pos, ctx)
        new_rem.append(nc)
    new_cache["rem"] = new_rem

    logits = T.unembed(cfg, params, x, shard)
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill (build the cache for a whole prompt)
# ---------------------------------------------------------------------------

def _attn_prefill_kv(cfg, p, h, ctx):
    Dh = cfg.resolved_head_dim
    KV = cfg.num_kv_heads
    k = T._heads(T._proj(h, p["wk"], p.get("bk")), KV, Dh)
    v = T._heads(T._proj(h, p["wv"], p.get("bv")), KV, Dh)
    if cfg.qk_norm:
        k = L.rms_norm(k, p["kn"], cfg.norm_eps)
    if cfg.rope in ("rope", "mrope"):
        k = L.apply_rope(k, ctx.cos, ctx.sin)
    return k, v


def block_prefill(cfg: ModelConfig, kind: str, p, x, ctx: T.Ctx):
    """Forward one block over the full prompt, returning its cache entry."""
    S = x.shape[1]
    cache: Dict[str, Any] = {}
    if kind == "attn":
        if cfg.attention == "mla":
            h = L.rms_norm(x, p["attn"]["ln"], cfg.norm_eps)
            kv = T._proj(h, p["attn"]["wkv_a"])
            lat = L.rms_norm(kv[..., :cfg.kv_lora_rank], p["attn"]["kv_ln"],
                             cfg.norm_eps)
            kr = L.apply_rope(kv[..., cfg.kv_lora_rank:][:, :, None, :],
                              ctx.cos_r, ctx.sin_r)[:, :, 0]
            cache["lat"], cache["kr"] = lat, kr
            x = T.mla_forward(cfg, p["attn"], x, ctx)
        else:
            h = L.rms_norm(x, p["attn"]["ln"], cfg.norm_eps)
            k, v = _attn_prefill_kv(cfg, p["attn"], h, ctx)
            if _use_ring(cfg, S):
                W = cfg.sliding_window
                shift = (S - W) % W          # align slots to p % W
                cache["k"] = jnp.roll(k[:, S - W:], shift, axis=1)
                cache["v"] = jnp.roll(v[:, S - W:], shift, axis=1)
                cache["kpos"] = jnp.roll(jnp.arange(S - W, S, dtype=jnp.int32),
                                         shift)
            else:
                cache["k"], cache["v"] = k, v
            window = cfg.sliding_window if cfg.family == "hybrid" else 0
            x = T.attn_forward(cfg, p["attn"], x, ctx, window=window)
    elif kind == "rglru":
        x, (hl, conv) = T.rglru_forward(cfg, p["rec"], x, ctx)
        cache["h"], cache["conv"] = hl.astype(jnp.float32), conv
    elif kind == "ssd":
        x, (hl, conv) = T.ssd_forward(cfg, p["ssd"], x, ctx)
        cache["h"], cache["conv"] = hl, conv
    if "xattn" in p and ctx.enc_out is not None:
        xp = p["xattn"]
        hk = L.rms_norm(ctx.enc_out, xp["ln"], cfg.norm_eps)
        cache["xk"] = T._heads(T._proj(hk, xp["wk"]), cfg.num_kv_heads,
                               cfg.resolved_head_dim)
        cache["xv"] = T._heads(T._proj(hk, xp["wv"]), cfg.num_kv_heads,
                               cfg.resolved_head_dim)
        x = T.attn_forward(cfg, xp, x, ctx, kv_override=(cache["xk"], cache["xv"]),
                           cross=True)
    if "ffn" in p:
        x = T.ffn_forward(cfg, p["ffn"], x, ctx)
    return ctx.shard(x, "act"), cache


def prefill(cfg: ModelConfig, params, tokens, *, encoder_frames=None,
            frontend_embeds=None, shard=lambda x, k: x):
    """Run the prompt, returning (logits_last (B,1,V), cache)."""
    B, S = tokens.shape
    x = T.embed_tokens(cfg, params, tokens)
    if cfg.frontend == "vision_patches" and frontend_embeds is not None:
        pe = T._proj(frontend_embeds.astype(x.dtype), params["patch_proj"])
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
    if cfg.rope == "learned":
        x = x + params["pos_embed"][jnp.arange(S)].astype(x.dtype)
    x = shard(x, "act")

    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
    ctx = T.Ctx(cfg=cfg, shard=shard)
    if cfg.rope in ("rope", "mrope"):
        ctx.cos, ctx.sin = T._rope_ctx(cfg, positions, cfg.resolved_head_dim)
        if cfg.attention == "mla":
            ctx.cos_r, ctx.sin_r = T._rope_ctx(cfg, positions, cfg.rope_head_dim)
            ctx.cos = ctx.sin = None
    if encoder_frames is not None and (cfg.encoder_layers or cfg.cross_attention):
        ctx.enc_out = (T.encode(cfg, params, encoder_frames, shard)
                       if cfg.encoder_layers else encoder_frames.astype(x.dtype))

    pattern = cfg.block_pattern

    def group_fn(xc, gp):
        caches = {}
        for j, kind in enumerate(pattern):
            key = f"b{j}_{kind}"
            xc, caches[key] = block_prefill(cfg, kind, gp[key], xc, ctx)
        return xc, caches

    gf = jax.checkpoint(group_fn) if cfg.remat else group_fn
    cache: Dict[str, Any] = {"pos": jnp.asarray(S, jnp.int32)}
    if params["blocks"]:
        x, cache["blocks"] = lax.scan(gf, x, params["blocks"])
    else:
        cache["blocks"] = {}
    cache["rem"] = []
    for j, lp in enumerate(params["rem"]):
        kind = pattern[j % len(pattern)]
        x, c = block_prefill(cfg, kind, lp, x, ctx)
        cache["rem"].append(c)

    logits = T.unembed(cfg, params, x[:, -1:], shard)
    return logits, cache
