"""The unified architecture family.

One functional model covers all ten assigned architectures: dense GQA
(optionally qk-norm / QKV-bias), MLA (latent attention), MoE, hybrid
RG-LRU + local attention, Mamba-2 SSD, M-RoPE VLM backbones and the Whisper
encoder-decoder.  Layers are stacked per repeating ``block_pattern`` group
and scanned (``lax.scan``) for O(1) HLO size; pattern remainders are applied
as unscanned layers.

Params are described by ``PDef`` descriptors carrying *logical* axis names;
``repro.distributed.sharding`` maps those to mesh ``PartitionSpec``s.  The
same descriptors drive ``jax.eval_shape``-based spec trees for the dry-run
(no allocation).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L

Pytree = Any


# ---------------------------------------------------------------------------
# param descriptors
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis names (or None)
    init: str = "normal"                     # normal | zeros | ones | lru | ssm_a | dtbias
    scale: float = 0.02

    def with_stack(self, n: int) -> "PDef":
        return PDef((n,) + self.shape, ("layer",) + self.axes, self.init, self.scale)


def _dense(din, dout, ax_in="fsdp", ax_out="tp", scale=0.02):
    return PDef((din, dout), (ax_in, ax_out), "normal", scale)


def _norm(d):
    return PDef((d,), (None,), "zeros")


# ---------------------------------------------------------------------------
# per-block param definitions
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig, cross: bool = False) -> Dict[str, PDef]:
    D = cfg.d_model
    Dh = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    out: Dict[str, PDef] = {"ln": _norm(D)}
    if cfg.attention == "mla" and not cross:
        qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
        dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
        out.update(
            wq_a=_dense(D, qr), q_ln=_norm(qr),
            wq_b=_dense(qr, H * (dn + dr)),
            wkv_a=_dense(D, kvr + dr, ax_out=None), kv_ln=_norm(kvr),
            wk_b=_dense(kvr, H * dn),
            wv_b=_dense(kvr, H * dv),
            wo=_dense(H * dv, D, ax_in="tp", ax_out="fsdp",
                      scale=0.02 / math.sqrt(2 * max(cfg.num_layers, 1))),
        )
        return out
    out.update(
        wq=_dense(D, H * Dh),
        wk=_dense(D, KV * Dh),
        wv=_dense(D, KV * Dh),
        wo=_dense(H * Dh, D, ax_in="tp", ax_out="fsdp",
                  scale=0.02 / math.sqrt(2 * max(cfg.num_layers, 1))),
    )
    if cfg.qkv_bias and not cross:
        out.update(bq=PDef((H * Dh,), ("tp",), "zeros"),
                   bk=PDef((KV * Dh,), ("tp",), "zeros"),
                   bv=PDef((KV * Dh,), ("tp",), "zeros"))
    if cfg.qk_norm and not cross:
        out.update(qn=_norm(Dh), kn=_norm(Dh))
    return out


def mlp_defs(cfg: ModelConfig) -> Dict[str, PDef]:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "ln": _norm(D),
        "w1": _dense(D, F),
        "w3": _dense(D, F),
        "w2": _dense(F, D, ax_in="tp", ax_out="fsdp",
                     scale=0.02 / math.sqrt(2 * max(cfg.num_layers, 1))),
    }


def moe_defs(cfg: ModelConfig) -> Dict[str, PDef]:
    D = cfg.d_model
    E, Fe = cfg.num_experts, (cfg.moe_d_ff or cfg.d_ff)
    return {
        "ln": _norm(D),
        "wg": PDef((D, E), (None, None), "normal"),
        "w1": PDef((E, D, Fe), ("expert", "fsdp", None), "normal"),
        "w3": PDef((E, D, Fe), ("expert", "fsdp", None), "normal"),
        "w2": PDef((E, Fe, D), ("expert", None, "fsdp"), "normal",
                   0.02 / math.sqrt(2 * max(cfg.num_layers, 1))),
    }


def rglru_defs(cfg: ModelConfig) -> Dict[str, PDef]:
    D = cfg.d_model
    W = D  # lru width = d_model (RecurrentGemma-2B)
    return {
        "ln": _norm(D),
        "wx": _dense(D, W),
        "wy": _dense(D, W),
        "conv_w": PDef((4, W), (None, "tp"), "normal", 0.1),
        "wga": _dense(W, W, ax_in="tp", ax_out=None),
        "bga": PDef((W,), (None,), "zeros"),
        "wgx": _dense(W, W, ax_in="tp", ax_out=None),
        "bgx": PDef((W,), (None,), "zeros"),
        "log_a": PDef((W,), (None,), "lru"),
        "wo": _dense(W, D, ax_in="tp", ax_out="fsdp",
                     scale=0.02 / math.sqrt(2 * max(cfg.num_layers, 1))),
    }


def ssd_defs(cfg: ModelConfig) -> Dict[str, PDef]:
    D = cfg.d_model
    din = cfg.ssm_expand * D
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    H = din // cfg.ssm_head_dim
    conv_ch = din + 2 * G * N
    return {
        "ln": _norm(D),
        "in_proj": _dense(D, 2 * din + 2 * G * N + H),
        "conv_w": PDef((cfg.ssm_conv, conv_ch), (None, "tp"), "normal", 0.1),
        "a_log": PDef((H,), (None,), "ssm_a"),
        "d_skip": PDef((H,), (None,), "ones"),
        "dt_bias": PDef((H,), (None,), "dtbias"),
        "out_ln": _norm(din),
        "out_proj": _dense(din, D, ax_in="tp", ax_out="fsdp",
                           scale=0.02 / math.sqrt(2 * max(cfg.num_layers, 1))),
    }


def block_defs(cfg: ModelConfig, kind: str, decoder: bool = True) -> Dict[str, Any]:
    """One block = mixer (+ optional cross-attn) (+ FFN)."""
    d: Dict[str, Any] = {}
    if kind == "attn":
        d["attn"] = attn_defs(cfg)
    elif kind == "rglru":
        d["rec"] = rglru_defs(cfg)
    elif kind == "ssd":
        d["ssd"] = ssd_defs(cfg)
    else:
        raise ValueError(kind)
    if decoder and cfg.cross_attention:
        d["xattn"] = attn_defs(cfg, cross=True)
    if kind != "ssd":  # mamba2 blocks have no separate FFN (d_ff = 0)
        d["ffn"] = moe_defs(cfg) if cfg.num_experts else mlp_defs(cfg)
    return d


# ---------------------------------------------------------------------------
# whole-model param definitions
# ---------------------------------------------------------------------------

def _stack_tree(tree: Pytree, n: int) -> Pytree:
    return jax.tree.map(lambda pd: pd.with_stack(n), tree,
                        is_leaf=lambda x: isinstance(x, PDef))


def param_defs(cfg: ModelConfig) -> Pytree:
    D, V = cfg.d_model, cfg.vocab_size
    period = len(cfg.block_pattern)
    groups, rem = divmod(cfg.num_layers, period)

    Vp = cfg.padded_vocab      # Megatron-style padding: vocab dim always
    defs: Dict[str, Any] = {   # shards on the production mesh
        "embed": PDef((Vp, D), ("vocab", None), "normal", 1.0 / math.sqrt(D)),
        "final_norm": _norm(D),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = PDef((D, Vp), (None, "vocab"), "normal")
    if cfg.rope == "learned":
        defs["pos_embed"] = PDef((cfg.max_position, D), (None, None), "normal", 0.01)

    group_tree = {f"b{j}_{kind}": block_defs(cfg, kind)
                  for j, kind in enumerate(cfg.block_pattern)}
    defs["blocks"] = _stack_tree(group_tree, groups) if groups else {}
    defs["rem"] = [block_defs(cfg, cfg.block_pattern[j % period])
                   for j in range(rem)]

    if cfg.encoder_layers:
        enc_block = {"attn": attn_defs(cfg), "ffn": mlp_defs(cfg)}
        defs["encoder"] = {
            "blocks": _stack_tree(enc_block, cfg.encoder_layers),
            "final_norm": _norm(D),
            "pos_embed": PDef((cfg.encoder_seq, D), (None, None), "normal", 0.01),
        }
    if cfg.frontend == "vision_patches":
        # early-fusion projection for precomputed patch embeddings (stub frontend)
        defs["patch_proj"] = _dense(D, D)
    return defs


def _is_pdef(x):
    return isinstance(x, PDef)


def init_params(cfg: ModelConfig, key: jax.Array) -> Pytree:
    defs = param_defs(cfg)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_pdef)
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.dtype)

    def mk(pd: PDef, k):
        if pd.init == "zeros":
            return jnp.zeros(pd.shape, dtype)
        if pd.init == "ones":
            return jnp.ones(pd.shape, dtype)
        if pd.init == "lru":
            # a in (0.9, 0.999):  log_a = softplus^-1-ish init
            u = jax.random.uniform(k, pd.shape, jnp.float32, 0.9, 0.999)
            lam = jnp.log(jnp.expm1(-jnp.log(u) / 8.0))  # softplus(lam) = -ln(u)/8
            return lam.astype(jnp.float32)
        if pd.init == "ssm_a":
            u = jax.random.uniform(k, pd.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(jnp.float32)
        if pd.init == "dtbias":
            u = jax.random.uniform(k, pd.shape, jnp.float32, 1e-3, 0.1)
            return jnp.log(jnp.expm1(u)).astype(jnp.float32)  # inv-softplus
        return (jax.random.normal(k, pd.shape, jnp.float32) * pd.scale).astype(dtype)

    return treedef.unflatten([mk(pd, k) for pd, k in zip(leaves, keys)])


def param_shapes(cfg: ModelConfig) -> Pytree:
    """ShapeDtypeStructs for all params — no allocation (dry-run path)."""
    dtype = jnp.dtype(cfg.dtype)

    def mk(pd: PDef):
        dt = jnp.float32 if pd.init in ("lru", "ssm_a", "dtbias") else dtype
        return jax.ShapeDtypeStruct(pd.shape, dt)

    return jax.tree.map(mk, param_defs(cfg), is_leaf=_is_pdef)


def param_logical_axes(cfg: ModelConfig) -> Pytree:
    return jax.tree.map(lambda pd: pd.axes, param_defs(cfg), is_leaf=_is_pdef)


def count_params(cfg: ModelConfig) -> int:
    defs = param_defs(cfg)
    leaves = jax.tree.leaves(defs, is_leaf=_is_pdef)
    return int(sum(np.prod(pd.shape) for pd in leaves))


# ---------------------------------------------------------------------------
# forward helpers
# ---------------------------------------------------------------------------

@dataclass
class Ctx:
    """Per-call context shared across layers (closure for scans)."""
    cfg: ModelConfig
    cos: Optional[jax.Array] = None          # (B,S,half)
    sin: Optional[jax.Array] = None
    cos_r: Optional[jax.Array] = None        # MLA rope dims
    sin_r: Optional[jax.Array] = None
    enc_out: Optional[jax.Array] = None
    shard: Callable[[jax.Array, str], jax.Array] = lambda x, kind: x
    q_offset: Any = 0                        # int or traced scalar
    kv_len: Any = None


def _proj(x, w, b=None):
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def _heads(x, n, d):
    return x.reshape(x.shape[0], x.shape[1], n, d)


def _rope_ctx(cfg: ModelConfig, positions, head_dim):
    if cfg.rope == "mrope":
        return L.mrope_angles(positions, head_dim, cfg.rope_theta, sections=(1, 1, 1))
    return L.rope_angles(positions, head_dim, cfg.rope_theta)


# --- GQA attention block -----------------------------------------------------

def attn_forward(cfg: ModelConfig, p, x, ctx: Ctx, *, window=0,
                 kv_override=None, cross=False):
    """Standard (GQA) attention.  kv_override: (k, v) for cross-attention."""
    Dh = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    q = _heads(_proj(h, p["wq"], p.get("bq")), H, Dh)
    if kv_override is None:
        k = _heads(_proj(h, p["wk"], p.get("bk")), KV, Dh)
        v = _heads(_proj(h, p["wv"], p.get("bv")), KV, Dh)
    else:
        k, v = kv_override
    if cfg.qk_norm and not cross:
        q = L.rms_norm(q, p["qn"], cfg.norm_eps)
        if kv_override is None:
            k = L.rms_norm(k, p["kn"], cfg.norm_eps)
    if cfg.rope in ("rope", "mrope") and not cross:
        q = L.apply_rope(q, ctx.cos, ctx.sin)
        if kv_override is None:
            k = L.apply_rope(k, ctx.cos, ctx.sin)
    o = L.blocked_attention(
        q, k, v, causal=not cross, window=window, chunk=cfg.attn_chunk,
        unroll=cfg.attn_unroll, q_offset=ctx.q_offset if not cross else 0,
        kv_len=ctx.kv_len if not cross else None)
    o = o.reshape(x.shape[0], x.shape[1], H * v.shape[-1])
    return x + _proj(o, p["wo"])


# --- MLA attention block -----------------------------------------------------

def mla_forward(cfg: ModelConfig, p, x, ctx: Ctx):
    H = cfg.num_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    cq = L.rms_norm(_proj(h, p["wq_a"]), p["q_ln"], cfg.norm_eps)
    q = _heads(_proj(cq, p["wq_b"]), H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = _proj(h, p["wkv_a"])
    lat = L.rms_norm(kv[..., :cfg.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora_rank:][:, :, None, :]        # (B,S,1,dr)
    q_rope = L.apply_rope(q_rope, ctx.cos_r, ctx.sin_r)
    k_rope = L.apply_rope(k_rope, ctx.cos_r, ctx.sin_r)
    k_nope = _heads(_proj(lat, p["wk_b"]), H, dn)
    v = _heads(_proj(lat, p["wv_b"]), H, dv)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (dr,))],
                         axis=-1)
    o = L.blocked_attention(qf, kf, v, causal=True, chunk=cfg.attn_chunk,
                            unroll=cfg.attn_unroll, q_offset=ctx.q_offset,
                            kv_len=ctx.kv_len)
    o = o.reshape(x.shape[0], x.shape[1], H * dv)
    return x + _proj(o, p["wo"])


# --- FFN ----------------------------------------------------------------------

def ffn_forward(cfg: ModelConfig, p, x, ctx: Ctx):
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    if cfg.num_experts:
        B, S, D = h.shape
        mesh = getattr(ctx.shard, "mesh", None)
        rules = getattr(ctx.shard, "rules", None)
        if (mesh is not None and "model" in mesh.shape
                and mesh.shape["model"] > 1 and cfg.moe_impl != "gather"
                and cfg.num_experts % mesh.shape["model"] == 0):
            # expert-parallel fast paths (shard_map; see distributed.moe_ep)
            from repro.distributed import moe_ep
            from repro.distributed.sharding import _fit_axes
            baxes = _fit_axes(B, [a for a in rules.get("batch", ())
                                  if a in mesh.shape], mesh)
            kw = dict(num_experts=cfg.num_experts, k=cfg.experts_per_token,
                      capacity_factor=cfg.moe_capacity_factor, act=cfg.act,
                      mesh=mesh, batch_axes=baxes)
            fe = cfg.moe_d_ff or cfg.d_ff
            if (cfg.moe_impl == "ep_resident" and "data" in mesh.shape
                    and mesh.shape["data"] > 1 and "data" in baxes
                    and fe % mesh.shape["data"] == 0):
                y, aux = moe_ep.moe_ffn_ep_resident(
                    h, p["wg"], p["w1"], p["w3"], p["w2"], **kw)
            else:
                y, aux = moe_ep.moe_ffn_ep(
                    h, p["wg"], p["w1"], p["w3"], p["w2"], **kw)
            return x + ctx.shard(y, "act")
        flat = h.reshape(B * S, D)
        # token-block scan bounds dispatch memory at large T
        bt = 0
        if cfg.moe_block_tokens and B * S > 2 * cfg.moe_block_tokens:
            bt = cfg.moe_block_tokens
            while (B * S) % bt:
                bt //= 2
        y, aux = L.moe_ffn(
            flat, p["wg"].astype(h.dtype), p["w1"], p["w3"], p["w2"],
            num_experts=cfg.num_experts, k=cfg.experts_per_token,
            capacity_factor=cfg.moe_capacity_factor, act=cfg.act,
            block_tokens=bt)
        return x + ctx.shard(y.reshape(B, S, D), "act")
    a = L.act_fn(cfg.act)(_proj(h, p["w1"]))
    y = _proj(a * _proj(h, p["w3"]), p["w2"])
    return x + y


# --- RG-LRU block ---------------------------------------------------------------

def rglru_forward(cfg: ModelConfig, p, x, ctx: Ctx, h0=None, conv0=None):
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    gate = L.act_fn("gelu")(_proj(h, p["wy"]))
    xb = _proj(h, p["wx"])
    xb, conv_state = L.causal_conv1d(xb, p["conv_w"], conv0)
    ga = _proj(xb, p["wga"], p["bga"])
    gx = _proj(xb, p["wgx"], p["bgx"])
    seq, h_last = L.rglru(xb, gx, ga, p["log_a"], h0)
    y = _proj(seq * gate, p["wo"])
    return x + y, (h_last, conv_state)


# --- Mamba-2 SSD block ------------------------------------------------------------

def ssd_forward(cfg: ModelConfig, p, x, ctx: Ctx, h0=None, conv0=None):
    D = cfg.d_model
    din = cfg.ssm_expand * D
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    H = din // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = _proj(h, p["in_proj"])
    z, xs, BC, dt = jnp.split(zxbcdt, [din, 2 * din, 2 * din + 2 * G * N], axis=-1)
    conv_in = jnp.concatenate([xs, BC], axis=-1)
    conv_out, conv_state = L.causal_conv1d(conv_in, p["conv_w"], conv0)
    conv_out = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(conv_out, [din, din + G * N], axis=-1)
    Bsz, S = x.shape[0], x.shape[1]
    xh = xs.reshape(Bsz, S, H, P)
    Bm = Bm.reshape(Bsz, S, G, N)
    Cm = Cm.reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, h_last = L.ssd_chunked(xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk, h0=h0)
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, din)
    y = L.rms_norm(y * jax.nn.silu(z), p["out_ln"], cfg.norm_eps)
    return x + _proj(y, p["out_proj"]), (h_last, conv_state)


# ---------------------------------------------------------------------------
# full forward (train / prefill, no cache)
# ---------------------------------------------------------------------------

def apply_block(cfg: ModelConfig, kind: str, p, x, ctx: Ctx):
    if kind == "attn":
        if cfg.attention == "mla":
            x = mla_forward(cfg, p["attn"], x, ctx)
        else:
            window = cfg.sliding_window if cfg.family == "hybrid" else 0
            x = attn_forward(cfg, p["attn"], x, ctx, window=window)
    elif kind == "rglru":
        x, _ = rglru_forward(cfg, p["rec"], x, ctx)
    elif kind == "ssd":
        x, _ = ssd_forward(cfg, p["ssd"], x, ctx)
    if "xattn" in p and ctx.enc_out is not None:
        xp = p["xattn"]
        hk = L.rms_norm(ctx.enc_out, xp["ln"], cfg.norm_eps)
        k = _heads(_proj(hk, xp["wk"]), cfg.num_kv_heads, cfg.resolved_head_dim)
        v = _heads(_proj(hk, xp["wv"]), cfg.num_kv_heads, cfg.resolved_head_dim)
        x = attn_forward(cfg, xp, x, ctx, kv_override=(k, v), cross=True)
    if "ffn" in p:
        x = ffn_forward(cfg, p["ffn"], x, ctx)
    return ctx.shard(x, "act")


def run_decoder_blocks(cfg: ModelConfig, params, x, ctx: Ctx):
    pattern = cfg.block_pattern
    period = len(pattern)

    def group_fn(xc, gp):
        for j, kind in enumerate(pattern):
            xc = apply_block(cfg, kind, gp[f"b{j}_{kind}"], xc, ctx)
        return xc

    gf = jax.checkpoint(group_fn) if cfg.remat else group_fn
    blocks = params["blocks"]
    if blocks:
        if cfg.scan_layers:
            x, _ = lax.scan(lambda c, gp: (gf(c, gp), None), x, blocks)
        else:
            G = jax.tree.leaves(blocks)[0].shape[0]
            for g in range(G):
                x = gf(x, jax.tree.map(lambda a: a[g], blocks))
    for j, lp in enumerate(params["rem"]):
        kind = pattern[j % period]

        def rem_fn(lp_, x_, _kind=kind):
            return apply_block(cfg, _kind, lp_, x_, ctx)   # ctx via closure

        x = jax.checkpoint(rem_fn)(lp, x) if cfg.remat else rem_fn(lp, x)
    return x


def encode(cfg: ModelConfig, params, frames, shard=lambda x, k: x):
    """Whisper-style bidirectional encoder over precomputed frame embeddings."""
    enc = params["encoder"]
    x = frames + enc["pos_embed"][None, : frames.shape[1]].astype(frames.dtype)
    ctx = Ctx(cfg=cfg, shard=shard)

    def block(xc, bp):
        h = L.rms_norm(xc, bp["attn"]["ln"], cfg.norm_eps)
        Dh = cfg.resolved_head_dim
        q = _heads(_proj(h, bp["attn"]["wq"]), cfg.num_heads, Dh)
        k = _heads(_proj(h, bp["attn"]["wk"]), cfg.num_kv_heads, Dh)
        v = _heads(_proj(h, bp["attn"]["wv"]), cfg.num_kv_heads, Dh)
        o = L.blocked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk,
                                unroll=cfg.attn_unroll)
        o = o.reshape(xc.shape[0], xc.shape[1], cfg.num_heads * Dh)
        xc = xc + _proj(o, bp["attn"]["wo"])
        return ffn_forward(cfg, bp["ffn"], xc, ctx)

    bf = jax.checkpoint(block) if cfg.remat else block
    x, _ = lax.scan(lambda c, bp: (bf(c, bp), None), x, enc["blocks"])
    return L.rms_norm(x, enc["final_norm"], cfg.norm_eps)


def embed_tokens(cfg: ModelConfig, params, tokens):
    x = params["embed"][tokens]
    if cfg.family == "hybrid":                       # gemma-style embed scale
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(cfg: ModelConfig, params, x, shard=lambda x, k: x):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask padding columns (cheap additive bias, fused by XLA)
        pad_mask = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                             0.0, -1e30).astype(logits.dtype)
        logits = logits + pad_mask[None, None, :]
    return shard(logits, "logits")


def forward(cfg: ModelConfig, params, tokens, *, positions=None,
            frontend_embeds=None, encoder_frames=None,
            shard=lambda x, k: x, q_offset=0, kv_len=None) -> jax.Array:
    """Full forward over a token block -> logits (train / prefill)."""
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    if cfg.frontend == "vision_patches" and frontend_embeds is not None:
        # early fusion: patch embeddings replace the leading positions
        pe = _proj(frontend_embeds.astype(x.dtype), params["patch_proj"])
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
    if cfg.rope == "learned":
        base = q_offset if not isinstance(q_offset, int) else q_offset
        pos_ids = jnp.arange(S) + base
        x = x + params["pos_embed"][pos_ids].astype(x.dtype)
    x = shard(x, "act")

    if positions is None:
        pos1d = jnp.arange(S)[None, :] + (q_offset if not isinstance(q_offset, int) else q_offset)
        positions = jnp.broadcast_to(pos1d, (B, S))
        if cfg.rope == "mrope":
            positions = jnp.broadcast_to(positions[..., None], (B, S, 3))

    ctx = Ctx(cfg=cfg, shard=shard, q_offset=q_offset, kv_len=kv_len)
    if cfg.rope in ("rope", "mrope"):
        ctx.cos, ctx.sin = _rope_ctx(cfg, positions, cfg.resolved_head_dim)
        if cfg.attention == "mla":
            ctx.cos_r, ctx.sin_r = _rope_ctx(cfg, positions, cfg.rope_head_dim)
            ctx.cos = ctx.sin = None
    if encoder_frames is not None and (cfg.encoder_layers or cfg.cross_attention):
        # encoder_layers == 0 + cross_attention: pass-through (used by the
        # dry-run's layer-cost variant protocol)
        ctx.enc_out = (encode(cfg, params, encoder_frames, shard)
                       if cfg.encoder_layers else encoder_frames.astype(x.dtype))

    x = run_decoder_blocks(cfg, params, x, ctx)
    return unembed(cfg, params, x, shard)


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Cross-entropy safe for vocab-sharded logits (no cross-shard gather)."""
    lg = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1))
    oh = labels[..., None] == jnp.arange(logits.shape[-1])[None, None, :]
    lab = jnp.sum(jnp.where(oh, lg, 0.0), axis=-1)
    return jnp.mean(lse - lab)
