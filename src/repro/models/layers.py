"""Building blocks shared by every architecture family.

Everything is functional: params are plain pytrees, ops are pure functions.
Attention is *blocked* (flash-style chunking over queries) in the pure-JAX
path so activation memory stays bounded at 32k+ sequence lengths; the Pallas
kernels in ``repro.kernels`` are the TPU-target versions of the same tiles.

Design notes for the dry-run (CPU, 512 placeholder devices):
  * The q-chunk loop may be UNROLLED (``unroll=True``) so XLA's
    ``cost_analysis`` counts attention FLOPs exactly (a ``while`` body is
    otherwise counted once, not x trip-count).
  * Linear recurrences (RG-LRU, SSD inter-chunk state) use
    ``lax.associative_scan`` — log-depth combinator trees, no while loops,
    so their FLOPs are counted correctly as well.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# norms & activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[name]


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE and 3-section M-RoPE)
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (..., S) -> cos/sin (..., S, head_dim//2), fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions: jax.Array, head_dim: int, theta: float,
                 sections=(1, 1, 1)) -> Tuple[jax.Array, jax.Array]:
    """M-RoPE: positions (B, S, 3) (t/h/w ids); frequency bands split into
    three sections proportionally to ``sections``."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    total = sum(sections)
    bounds = []
    acc = 0
    for s in sections:
        acc += s
        bounds.append((half * acc) // total)
    band = jnp.zeros((half,), dtype=jnp.int32)
    prev = 0
    for i, b in enumerate(bounds):
        band = band.at[prev:b].set(i)
        prev = b
    # pick the position channel (t/h/w) for each frequency band
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),                 # (B, S, 3)
        jnp.broadcast_to(band[None, None, :],
                         positions.shape[:-1] + (half,)),
        axis=-1,
    )                                                  # (B, S, half)
    ang = pos * freqs[None, None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, Dh); cos/sin (B, S, Dh//2) -> rotate-half RoPE."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# blocked attention (the pure-JAX analogue of kernels/flash_attention)
# ---------------------------------------------------------------------------

def _attn_block(qc: jax.Array, k: jax.Array, v: jax.Array, *,
                q_start, kv_start: int, causal: bool, window: int,
                kv_len: Optional[jax.Array]) -> jax.Array:
    """One query block attending to a K/V span.

    qc (B, C, H, Dh); k/v (B, Skv, KV, Dv).  GQA via head grouping.
    ``q_start`` may be a traced scalar (position offset of qc within the
    sequence); ``kv_start`` likewise for k.  ``kv_len`` optionally masks the
    valid KV prefix (decode with preallocated cache).
    """
    B, C, H, Dh = qc.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = qc.reshape(B, C, KV, G, Dh)
    scores = jnp.einsum("bckgd,bskd->bkgcs", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(Dh)
    qpos = q_start + jnp.arange(C)                      # (C,)
    kpos = kv_start + jnp.arange(Skv)                   # (Skv,)
    mask = jnp.ones((C, Skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    if kv_len is not None:
        # scalar (possibly traced) valid-prefix length, shared across batch
        mask &= kpos[None, :] < kv_len
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgcs,bskd->bckgd", w.astype(v.dtype), v)
    return out.reshape(B, C, H, v.shape[-1])


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0, chunk: int = 512,
                      unroll: bool = True, q_offset: int = 0,
                      kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Flash-style blocked attention over query chunks.

    q (B, Sq, H, Dh); k/v (B, Skv, KV, Dv).

    unroll=True (default): a *python* loop over query chunks.  Each chunk
    slices a static K/V span — for causal attention chunk i only reads
    K[: (i+1)*chunk], for windowed attention only its window.  This gives
    exact (not masked-full-span) attention FLOPs both on hardware and in
    XLA's ``cost_analysis``.

    unroll=False: a ``lax.scan`` with full-span masking, for sequences where
    unrolling would bloat the HLO.
    """
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    if Sq <= chunk or Sq % chunk != 0:
        return _attn_block(q, k, v, q_start=q_offset, kv_start=0,
                           causal=causal, window=window, kv_len=kv_len)
    nc = Sq // chunk

    if unroll:
        outs = []
        for i in range(nc):
            qc = lax.slice_in_dim(q, i * chunk, (i + 1) * chunk, axis=1)
            qs_start = q_offset + i * chunk
            if window:
                span = min(Skv, window + chunk)
                start = max(0, min(qs_start + chunk - span, Skv - span))
            elif causal and q_offset == 0:
                start, span = 0, min(Skv, (i + 1) * chunk)
            else:
                start, span = 0, Skv
            kc = lax.slice_in_dim(k, start, start + span, axis=1)
            vc = lax.slice_in_dim(v, start, start + span, axis=1)
            outs.append(_attn_block(qc, kc, vc, q_start=qs_start,
                                    kv_start=start, causal=causal,
                                    window=window, kv_len=kv_len))
        return jnp.concatenate(outs, axis=1)

    qs = jnp.moveaxis(q.reshape(B, nc, chunk, H, Dh), 1, 0)   # (nc, B, C, H, Dh)
    span = min(Skv, window + chunk) if window else None

    def body(_, inp):
        qc, i = inp
        qs_start = q_offset + i * chunk
        if span is not None and span < Skv:
            start = jnp.clip(qs_start + chunk - span, 0, Skv - span)
            kc = lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vc = lax.dynamic_slice_in_dim(v, start, span, axis=1)
            out = _attn_block(qc, kc, vc, q_start=qs_start, kv_start=start,
                              causal=causal, window=window, kv_len=kv_len)
        else:
            out = _attn_block(qc, k, v, q_start=qs_start, kv_start=0,
                              causal=causal, window=window, kv_len=kv_len)
        return None, out

    _, o = lax.scan(body, None, (qs, jnp.arange(nc)))
    return jnp.moveaxis(o, 0, 1).reshape(B, Sq, H, v.shape[-1])


# ---------------------------------------------------------------------------
# MoE with capacity-based sort-free dispatch (gather/scatter, no one-hot GEMM)
# ---------------------------------------------------------------------------

def moe_ffn(x: jax.Array, gate_w: jax.Array, w1: jax.Array, w3: jax.Array,
            w2: jax.Array, *, num_experts: int, k: int, capacity_factor: float,
            act: str = "silu", block_tokens: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Top-k MoE FFN.  x (T, D) -> (T, D), plus aux load-balance loss.

    Dispatch is a scatter into per-expert slots (no T x E x C one-hot einsum);
    combine is a gather.  ``block_tokens`` > 0 processes tokens in sequential
    blocks (scan) to bound dispatch memory at large T.
    """
    T, D = x.shape
    E = num_experts

    def one_block(xb):
        Tb = xb.shape[0]
        C = max(8, int(math.ceil(Tb * k * capacity_factor / E)))
        logits = jnp.einsum("td,de->te", xb, gate_w).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = lax.top_k(probs, k)                    # (Tb, k)
        topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)
        flat_e = topi.reshape(-1)                            # (Tb*k,)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos_in_e = (jnp.cumsum(oh, axis=0) - 1)
        pos_in_e = jnp.sum(pos_in_e * oh, axis=-1)           # (Tb*k,)
        keep = pos_in_e < C
        slot = jnp.where(keep, flat_e * C + pos_in_e, E * C)  # overflow -> E*C
        # dispatch: scatter token rows into slots
        tok_idx = jnp.repeat(jnp.arange(Tb), k)
        buf = jnp.zeros((E * C + 1, D), dtype=xb.dtype).at[slot].set(xb[tok_idx])
        xe = buf[: E * C].reshape(E, C, D)
        h = act_fn(act)(jnp.einsum("ecd,edf->ecf", xe, w1))
        h = h * jnp.einsum("ecd,edf->ecf", xe, w3)
        ye = jnp.einsum("ecf,efd->ecd", h, w2)
        yflat = jnp.concatenate(
            [ye.reshape(E * C, D), jnp.zeros((1, D), dtype=ye.dtype)], axis=0)
        yk = yflat[slot].reshape(Tb, k, D)
        out = jnp.einsum("tkd,tk->td", yk, topv.astype(yk.dtype))
        # aux: load-balance loss (Switch-style)
        me = probs.mean(axis=0)                              # (E,)
        ce = jnp.bincount(flat_e, length=E).astype(jnp.float32) / (Tb * k)
        aux = E * jnp.sum(me * ce)
        return out, aux

    if block_tokens and T > block_tokens and T % block_tokens == 0:
        nb = T // block_tokens
        xs = x.reshape(nb, block_tokens, D)
        def body(_, xb):
            return None, one_block(xb)
        _, (outs, auxs) = lax.scan(body, None, xs)
        return outs.reshape(T, D), jnp.mean(auxs)
    return one_block(x)


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma) — associative-scan linear recurrence
# ---------------------------------------------------------------------------

def rglru(x: jax.Array, gate_x: jax.Array, gate_a: jax.Array, log_a: jax.Array,
          h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Real-Gated Linear Recurrent Unit.

    x, gate_x, gate_a: (B, S, W).  log_a: (W,) learnable (Lambda).
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(c * log_sigmoid(Lambda) * r_t),  c = -8.
    Returns (h_seq (B,S,W), h_last (B,W)).
    """
    c = -8.0
    r = jax.nn.sigmoid(gate_a.astype(jnp.float32))
    i = jax.nn.sigmoid(gate_x.astype(jnp.float32))
    log_a_t = c * r * jax.nn.softplus(log_a.astype(jnp.float32))      # log a_t <= 0
    a = jnp.exp(log_a_t)
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a_t), 1e-12))
    b = mult * i * x.astype(jnp.float32)
    if h0 is not None:
        # fold h0 into the first step
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    a_s, h = lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1].astype(x.dtype)


def rglru_step(xt, gxt, gat, log_a, h_prev):
    """Single-token RG-LRU update for decode.  xt (B, W)."""
    c = -8.0
    r = jax.nn.sigmoid(gat.astype(jnp.float32))
    i = jax.nn.sigmoid(gxt.astype(jnp.float32))
    log_a_t = c * r * jax.nn.softplus(log_a.astype(jnp.float32))
    a = jnp.exp(log_a_t)
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a_t), 1e-12))
    h = a * h_prev.astype(jnp.float32) + mult * i * xt.astype(jnp.float32)
    return h.astype(xt.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality), chunked
# ---------------------------------------------------------------------------

def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, *, chunk: int,
                h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Mamba-2 SSD forward.

    x  (B, S, H, P)   input heads
    dt (B, S, H)      softplus'd step sizes (>0)
    A  (H,)           negative state decay (A < 0 as -exp(A_log))
    Bm (B, S, G, N), Cm (B, S, G, N)  input/output projections (G groups)
    Returns (y (B, S, H, P), final_state (B, H, P, N)).

    Intra-chunk is the quadratic "attention-like" term; inter-chunk state is
    carried with an associative scan over chunk summaries (no while loop).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    rep = H // G

    xf = x.reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bf = Bm.reshape(Bsz, nc, Q, G, N)
    Cf = Cm.reshape(Bsz, nc, Q, G, N)

    dA = dtf * A.astype(jnp.float32)[None, None, None, :]     # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(dA, axis=2)                              # within-chunk cumsum
    seg_total = cum[:, :, -1, :]                              # (B,nc,H)

    # --- intra-chunk (quadratic within Q) ---------------------------------
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    Li = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    Lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(Li), 0.0)
    CB = jnp.einsum("bcqgn,bckgn->bcqkg", Cf.astype(jnp.float32),
                    Bf.astype(jnp.float32))                   # (B,nc,Q,Q,G)
    CB = jnp.repeat(CB, rep, axis=-1)                         # (B,nc,Q,Q,H)
    W = CB * Lmat * dtf[:, :, None, :, :]                     # weight on x_j
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", W, xf.astype(jnp.float32))

    # --- chunk state summaries --------------------------------------------
    # state_c = sum_j exp(seg_total - cum_j) * dt_j * B_j (x) x_j
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - cum)    # (B,nc,Q,H)
    Bh = jnp.repeat(Bf, rep, axis=3)                          # (B,nc,Q,H,N)
    wgt = (dtf * decay_to_end)[..., None]                     # (B,nc,Q,H,1)
    states = jnp.einsum("bcqhn,bcqhp->bchpn", Bh.astype(jnp.float32),
                        xf.astype(jnp.float32) * wgt)         # (B,nc,H,P,N)

    # --- inter-chunk recurrence over chunk dim (associative scan) ----------
    seg_decay = jnp.exp(seg_total)                            # (B,nc,H)
    if h0 is not None:
        states = states.at[:, 0].add(seg_decay[:, 0][..., None, None]
                                     * h0.astype(jnp.float32))

    def combine(p, q):
        a1, s1 = p
        a2, s2 = q
        return a1 * a2, a2[..., None, None] * s1 + s2

    _, carried = lax.associative_scan(combine, (seg_decay, states), axis=1)
    # state entering chunk c = carried[c-1]
    h_prev = jnp.concatenate(
        [jnp.zeros_like(carried[:, :1]) if h0 is None
         else h0.astype(jnp.float32)[:, None], carried[:, :-1]], axis=1)

    # --- inter-chunk contribution ------------------------------------------
    decay_from_start = jnp.exp(cum)                           # (B,nc,Q,H)
    Ch = jnp.repeat(Cf, rep, axis=3)                          # (B,nc,Q,H,N)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Ch.astype(jnp.float32), h_prev)
    y_inter = y_inter * decay_from_start[..., None]

    y = (y_intra + y_inter).reshape(Bsz, S, H, P).astype(x.dtype)
    return y, carried[:, -1].astype(jnp.float32)


def ssd_step(xt, dtt, A, Bt, Ct, h_prev):
    """Single-token SSD state update for decode.

    xt (B,H,P), dtt (B,H), Bt/Ct (B,G,N), h_prev (B,H,P,N) fp32.
    """
    G = Bt.shape[1]
    H = xt.shape[1]
    rep = H // G
    dA = jnp.exp(dtt.astype(jnp.float32) * A.astype(jnp.float32)[None, :])  # (B,H)
    Bh = jnp.repeat(Bt.astype(jnp.float32), rep, axis=1)     # (B,H,N)
    Ch = jnp.repeat(Ct.astype(jnp.float32), rep, axis=1)
    h = h_prev * dA[..., None, None] + (
        dtt.astype(jnp.float32)[..., None, None]
        * xt.astype(jnp.float32)[..., None] * Bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch)
    return y.astype(xt.dtype), h


def causal_conv1d(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv via explicit shifts (width K small).

    x (B, S, C), w (K, C).  Returns (y, new_state (B, K-1, C))."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), dtype=x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else jnp.zeros_like(pad)
    return y.astype(x.dtype), new_state
