"""The paper's Table I vision models in JAX (ResNet-50, EfficientNet-B0-ish,
FCN, YOLOv3, ViT), structurally faithful with a ``width`` multiplier for
CPU-scale smoke/demo runs.

Convolutions can execute through the DSA path: im2col patches ->
``kernels.ops.matmul`` (the systolic kernel) — the paper's compiler story.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Pytree = Any


def conv2d(x: jax.Array, w: jax.Array, stride: int = 1,
           use_kernel: bool = False) -> jax.Array:
    """x (B,H,W,C); w (kh,kw,C,O), SAME padding."""
    if not use_kernel:
        return lax.conv_general_dilated(
            x, w, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    kh, kw, c, o = w.shape
    patches = lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))      # (B,H',W',kh*kw*C)
    B, H2, W2, K = patches.shape
    m = B * H2 * W2
    from repro.kernels import ops
    # patches are (C, kh, kw)-ordered along the feature dim
    w2 = jnp.transpose(w, (2, 0, 1, 3)).reshape(K, o)
    out = ops.matmul_padded(patches.reshape(m, K), w2)
    return out.reshape(B, H2, W2, o)


def _init_conv(key, kh, kw, c, o):
    fan = kh * kw * c
    return jax.random.normal(key, (kh, kw, c, o)) * math.sqrt(2.0 / fan)


def batch_norm(x, scale, bias, eps=1e-5):
    m = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    v = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - m) * lax.rsqrt(v + eps) * scale + bias


# --------------------------------------------------------------------------
# ResNet-50 (bottleneck), width-scalable
# --------------------------------------------------------------------------

def resnet50_init(key, *, width: float = 1.0, classes: int = 1000) -> Pytree:
    ks = jax.random.split(key, 256)
    it = iter(range(256))
    w = lambda c: max(8, int(c * width))
    p: Dict[str, Any] = {"stem": _init_conv(ks[next(it)], 7, 7, 3, w(64))}
    spec = [(3, 64, 256), (4, 128, 512), (6, 256, 1024), (3, 512, 2048)]
    cin = w(64)
    blocks = []
    for i, (n, mid, out) in enumerate(spec):
        for j in range(n):
            stride = 2 if (j == 0 and i > 0) else 1
            blk = {
                "c1": _init_conv(ks[next(it)], 1, 1, cin, w(mid)),
                "c2": _init_conv(ks[next(it)], 3, 3, w(mid), w(mid)),
                "c3": _init_conv(ks[next(it)], 1, 1, w(mid), w(out)),
                "stride": stride,
            }
            if j == 0:
                blk["proj"] = _init_conv(ks[next(it)], 1, 1, cin, w(out))
            blocks.append(blk)
            cin = w(out)
    p["blocks"] = blocks
    p["head"] = jax.random.normal(ks[next(it)], (cin, classes)) * 0.01
    return p


def resnet50_apply(p: Pytree, x: jax.Array, use_kernel: bool = False) -> jax.Array:
    h = jax.nn.relu(conv2d(x, p["stem"], 2, use_kernel))
    h = lax.reduce_window(h, -jnp.inf, lax.max, (1, 3, 3, 1), (1, 2, 2, 1),
                          "SAME")
    for blk in p["blocks"]:
        s = blk["stride"]
        r = conv2d(h, blk["proj"], s, use_kernel) if "proj" in blk else h
        h2 = jax.nn.relu(conv2d(h, blk["c1"], 1, use_kernel))
        h2 = jax.nn.relu(conv2d(h2, blk["c2"], s, use_kernel))
        h2 = conv2d(h2, blk["c3"], 1, use_kernel)
        h = jax.nn.relu(h2 + r)
    h = jnp.mean(h, axis=(1, 2))
    return h @ p["head"]


# --------------------------------------------------------------------------
# EfficientNet-B0-style MBConv net
# --------------------------------------------------------------------------

def effnet_init(key, *, width: float = 1.0, classes: int = 1000) -> Pytree:
    ks = iter(jax.random.split(key, 128))
    w = lambda c: max(8, int(c * width))
    p = {"stem": _init_conv(next(ks), 3, 3, 3, w(32))}
    stages = [(1, 32, 16, 1), (2, 16, 24, 6), (2, 24, 40, 6), (3, 40, 80, 6),
              (1, 80, 112, 6)]
    blocks = []
    for n, cin, cout, exp in stages:
        for j in range(n):
            ci = w(cin) if j == 0 else w(cout)
            mid = ci * exp
            blocks.append({
                "expand": _init_conv(next(ks), 1, 1, ci, mid),
                "dw": jax.random.normal(next(ks), (3, 3, 1, mid)) * 0.3,
                "project": _init_conv(next(ks), 1, 1, mid, w(cout)),
                "stride": 2 if j == 0 and cin != cout and cin > 16 else 1,
            })
    p["blocks"] = blocks
    p["head_conv"] = _init_conv(next(ks), 1, 1, w(112), w(320))
    p["head"] = jax.random.normal(next(ks), (w(320), classes)) * 0.01
    return p


def effnet_apply(p, x, use_kernel: bool = False):
    h = jax.nn.silu(conv2d(x, p["stem"], 2, use_kernel))
    for blk in p["blocks"]:
        inp = h
        h2 = jax.nn.silu(conv2d(h, blk["expand"], 1, use_kernel))
        h2 = jax.nn.silu(lax.conv_general_dilated(
            h2, blk["dw"], (blk["stride"],) * 2, "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=h2.shape[-1]))
        h2 = conv2d(h2, blk["project"], 1, use_kernel)
        h = h2 + inp if h2.shape == inp.shape else h2
    h = jax.nn.silu(conv2d(h, p["head_conv"], 1, use_kernel))
    h = jnp.mean(h, axis=(1, 2))
    return h @ p["head"]


# --------------------------------------------------------------------------
# FCN (ResNet backbone + dense upsampling head)
# --------------------------------------------------------------------------

def fcn_init(key, *, width: float = 1.0, classes: int = 21) -> Pytree:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"backbone": resnet50_init(k1, width=width, classes=classes)}
    cin = max(8, int(2048 * width))
    p["score"] = _init_conv(k2, 3, 3, cin, classes)
    p["out"] = _init_conv(k3, 1, 1, classes, classes)
    return p


def fcn_apply(p, x, use_kernel: bool = False):
    bb = p["backbone"]
    h = jax.nn.relu(conv2d(x, bb["stem"], 2, use_kernel))
    h = lax.reduce_window(h, -jnp.inf, lax.max, (1, 3, 3, 1), (1, 2, 2, 1),
                          "SAME")
    for blk in bb["blocks"]:
        s = blk["stride"]
        r = conv2d(h, blk["proj"], s, use_kernel) if "proj" in blk else h
        h2 = jax.nn.relu(conv2d(h, blk["c1"], 1, use_kernel))
        h2 = jax.nn.relu(conv2d(h2, blk["c2"], s, use_kernel))
        h2 = conv2d(h2, blk["c3"], 1, use_kernel)
        h = jax.nn.relu(h2 + r)
    h = conv2d(h, p["score"], 1, use_kernel)
    # bilinear-ish upsample back to input resolution
    H = x.shape[1]
    h = jax.image.resize(h, (h.shape[0], H, H, h.shape[-1]), "linear")
    return conv2d(h, p["out"], 1, use_kernel)


# --------------------------------------------------------------------------
# YOLOv3 (darknet-53 trunk + 1 detection head; width-scalable)
# --------------------------------------------------------------------------

def yolov3_init(key, *, width: float = 1.0) -> Pytree:
    ks = iter(jax.random.split(key, 128))
    w = lambda c: max(8, int(c * width))
    p = {"stem": _init_conv(next(ks), 3, 3, 3, w(32))}
    trunk = []
    cin = w(32)
    for n, cout in [(1, 64), (1, 128), (2, 256), (2, 512), (1, 1024)]:
        stage = {"down": _init_conv(next(ks), 3, 3, cin, w(cout)), "res": []}
        for _ in range(n):
            stage["res"].append((
                _init_conv(next(ks), 1, 1, w(cout), w(cout) // 2),
                _init_conv(next(ks), 3, 3, w(cout) // 2, w(cout))))
        trunk.append(stage)
        cin = w(cout)
    p["trunk"] = trunk
    p["head"] = _init_conv(next(ks), 1, 1, cin, 255)
    return p


def yolov3_apply(p, x, use_kernel: bool = False):
    act = lambda v: jax.nn.leaky_relu(v, 0.1)
    h = act(conv2d(x, p["stem"], 1, use_kernel))
    for stage in p["trunk"]:
        h = act(conv2d(h, stage["down"], 2, use_kernel))
        for c1, c2 in stage["res"]:
            r = h
            h = act(conv2d(h, c1, 1, use_kernel))
            h = act(conv2d(h, c2, 1, use_kernel))
            h = h + r
    return conv2d(h, p["head"], 1, use_kernel)


# --------------------------------------------------------------------------
# ViT encoder (patch embeddings precomputed or raw image)
# --------------------------------------------------------------------------

def vit_init(key, *, layers=4, d=128, heads=4, d_ff=256, patch=16,
             classes=1000) -> Pytree:
    ks = iter(jax.random.split(key, 8 + 8 * layers))
    p = {"patch": jax.random.normal(next(ks), (patch * patch * 3, d)) * 0.02,
         "pos": jax.random.normal(next(ks), (1024, d)) * 0.01,
         "cls": jax.random.normal(next(ks), (1, 1, d)) * 0.02,
         "head": jax.random.normal(next(ks), (d, classes)) * 0.02,
         "blocks": []}
    for _ in range(layers):
        p["blocks"].append({
            "qkv": jax.random.normal(next(ks), (d, 3 * d)) * 0.02,
            "o": jax.random.normal(next(ks), (d, d)) * 0.02,
            "w1": jax.random.normal(next(ks), (d, d_ff)) * 0.02,
            "w2": jax.random.normal(next(ks), (d_ff, d)) * 0.02,
            "ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,)),
        })
    p["meta"] = {"heads": heads, "patch": patch}
    return p


def vit_apply(p, x, use_kernel: bool = False):
    """x (B, H, W, 3) image."""
    from repro.models.layers import rms_norm
    patch = p["meta"]["patch"]
    heads = p["meta"]["heads"]
    B, H, W, C = x.shape
    xp = x.reshape(B, H // patch, patch, W // patch, patch, C)
    xp = xp.transpose(0, 1, 3, 2, 4, 5).reshape(B, -1, patch * patch * C)
    h = xp @ p["patch"] + p["pos"][None, :xp.shape[1]]
    h = jnp.concatenate([jnp.broadcast_to(p["cls"], (B, 1, h.shape[-1])), h], 1)
    d = h.shape[-1]
    hd = d // heads
    for blk in p["blocks"]:
        hn = rms_norm(h, blk["ln1"])
        qkv = hn @ blk["qkv"]
        q, k, v = jnp.split(qkv.reshape(B, -1, 3, heads, hd), 3, axis=2)
        q, k, v = (t[:, :, 0].transpose(0, 2, 1, 3) for t in (q, k, v))
        if use_kernel:
            from repro.kernels import ops
            o = ops.attention(q, k, v, causal=False,
                              bq=min(128, q.shape[2]), bk=min(128, q.shape[2]))
        else:
            from repro.kernels import ref
            o = ref.attention_ref(q, k, v, causal=False)
        o = o.transpose(0, 2, 1, 3).reshape(B, -1, d)
        h = h + o @ blk["o"]
        hn = rms_norm(h, blk["ln2"])
        h = h + jax.nn.gelu(hn @ blk["w1"]) @ blk["w2"]
    return h[:, 0] @ p["head"]
