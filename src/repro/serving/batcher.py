"""Continuous batching for the serving path.

The DSCS scheduler admits requests run-to-completion per drive; at pod
scale the decode engine instead keeps a fixed slot pool: finished sequences
free their slot, queued requests prefill into it, and every decode step
advances all live slots together (the paper's Fig. 13 batching argument,
made continuous).  Pure-python slot manager + jittable state ops so the
same decode_step the dry-run lowers is what serves.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int
    arrived_step: int = 0
    out: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


@dataclass
class SlotState:
    rid: Optional[int] = None       # None = free


class ContinuousBatcher:
    """Fixed-slot continuous batching around (prefill_one, decode_batch).

    prefill_one(slot_idx, prompt) -> first token
    decode_batch(tokens (B,1), active_mask (B,)) -> next tokens (B,)
    """

    def __init__(self, num_slots: int, prefill_one: Callable,
                 decode_batch: Callable):
        self.slots = [SlotState() for _ in range(num_slots)]
        self.queue: List[Request] = []
        self.live: Dict[int, Request] = {}
        self.prefill_one = prefill_one
        self.decode_batch = decode_batch
        self.steps = 0
        self.stats = {"admitted": 0, "completed": 0, "decode_steps": 0,
                      "slot_busy_steps": 0}

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.rid is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            first = int(self.prefill_one(i, req.prompt))
            req.out.append(first)
            slot.rid = req.rid
            self.live[req.rid] = req
            self.stats["admitted"] += 1

    def step(self) -> None:
        """Admit into free slots, then advance every live slot one token."""
        self._admit()
        active = np.array([s.rid is not None for s in self.slots])
        if not active.any():
            return
        last = np.zeros((len(self.slots), 1), np.int32)
        for i, s in enumerate(self.slots):
            if s.rid is not None:
                last[i, 0] = self.live[s.rid].out[-1]
        nxt = np.asarray(self.decode_batch(jnp.asarray(last),
                                           jnp.asarray(active)))
        self.stats["decode_steps"] += 1
        self.stats["slot_busy_steps"] += int(active.sum())
        for i, s in enumerate(self.slots):
            if s.rid is None:
                continue
            req = self.live[s.rid]
            req.out.append(int(nxt[i]))
            if req.done:
                self.stats["completed"] += 1
                del self.live[s.rid]
                s.rid = None
        self.steps += 1

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        while (self.queue or self.live) and self.steps < max_steps:
            self.step()

    @property
    def slot_utilization(self) -> float:
        d = self.stats["decode_steps"] * len(self.slots)
        return self.stats["slot_busy_steps"] / d if d else 0.0
