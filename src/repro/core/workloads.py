"""Table I — the eight serverless applications and their DNN models.

Each workload is a 3-function pipeline (f1 pre-process, f2 ML inference,
f3 post/notify) with the paper's input/output payloads.  For the DSA tile
model every network is lowered to a GEMM list (convs via im2col; depthwise
convs and pre/post-processing count as vector-engine work).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Tuple

from repro.core.dsa import GemmShape


def conv(b, h, w, cin, cout, k, stride=1) -> GemmShape:
    oh, ow = h // stride, w // stride
    return GemmShape(m=b * oh * ow, k=cin * k * k, n=cout)


def fc(m, k, n, vec=0) -> GemmShape:
    return GemmShape(m=m, k=k, n=n, vector_ops=vec)


def resnet50_gemms(b=1, res=224) -> List[GemmShape]:
    g = [conv(b, res, res, 3, 64, 7, 2)]
    h = res // 4
    spec = [(3, 64, 256), (4, 128, 512), (6, 256, 1024), (3, 512, 2048)]
    cin = 64
    for i, (blocks, mid, out) in enumerate(spec):
        for j in range(blocks):
            stride = 2 if (j == 0 and i > 0) else 1
            g += [conv(b, h, h, cin, mid, 1),
                  conv(b, h, h, mid, mid, 3, stride),
                  conv(b, h // stride, h // stride, mid, out, 1)]
            if j == 0:
                g.append(conv(b, h, h, cin, out, 1, stride))
            h //= stride
            cin = out
    g.append(fc(b, 2048, 1000, vec=2048))
    return g


def efficientnet_b0_gemms(b=1) -> List[GemmShape]:
    # MBConv stages; depthwise convs -> vector-engine work
    g = [conv(b, 224, 224, 3, 32, 3, 2)]
    stages = [(1, 32, 16, 1, 112), (2, 16, 24, 6, 112), (2, 24, 40, 6, 56),
              (3, 40, 80, 6, 28), (3, 80, 112, 6, 14), (4, 112, 192, 6, 14),
              (1, 192, 320, 6, 7)]
    for blocks, cin, cout, exp, h in stages:
        for j in range(blocks):
            ci = cin if j == 0 else cout
            mid = ci * exp
            dw = b * h * h * mid * 9
            g += [fc(b * h * h, ci, mid, vec=dw), fc(b * h * h, mid, cout)]
    g += [conv(b, 7, 7, 320, 1280, 1), fc(b, 1280, 1000)]
    return g


def yolov3_gemms(b=1, res=416) -> List[GemmShape]:
    g = [conv(b, res, res, 3, 32, 3)]
    h, cin = res, 32
    for blocks, cout in [(1, 64), (2, 128), (8, 256), (8, 512), (4, 1024)]:
        g.append(conv(b, h, h, cin, cout, 3, 2))
        h //= 2
        for _ in range(blocks):
            g += [conv(b, h, h, cout, cout // 2, 1),
                  conv(b, h, h, cout // 2, cout, 3)]
        cin = cout
    for hh, c in [(13, 1024), (26, 512), (52, 256)]:   # detection heads
        g += [conv(b, hh, hh, c, c // 2, 1), conv(b, hh, hh, c // 2, c, 3),
              conv(b, hh, hh, c, 255, 1)]
    return g


def fcn_gemms(b=1) -> List[GemmShape]:
    g = resnet50_gemms(b)[:-1]
    g += [conv(b, 7, 7, 2048, 512, 3), conv(b, 28, 28, 512, 21, 1),
          conv(b, 224, 224, 21, 3, 1)]                 # upsample head
    return g


def transformer_gemms(b, seq, layers, d, heads, d_ff, vocab=0) -> List[GemmShape]:
    g = []
    hd = d // heads
    for _ in range(layers):
        g += [fc(b * seq, d, 3 * d),                   # QKV
              GemmShape(m=b * heads * seq, k=hd, n=seq),
              GemmShape(m=b * heads * seq, k=seq, n=hd, vector_ops=b * heads * seq * seq),
              fc(b * seq, d, d),
              fc(b * seq, d, d_ff, vec=b * seq * d_ff),
              fc(b * seq, d_ff, d)]
    if vocab:
        g.append(fc(b, d, vocab))
    return g


@dataclass(frozen=True)
class Workload:
    name: str
    description: str
    model: str
    params: float                    # parameter count
    input_bytes: int                 # f2 input payload
    output_bytes: int                # f2 output payload
    request_bytes: int               # raw user payload (f1 input)
    gemms: Tuple[GemmShape, ...] = field(default_factory=tuple)

    @property
    def weight_bytes(self) -> int:
        return int(self.params)      # int8 deployment (vector-engine quant)

    @property
    def flops(self) -> float:
        return sum(2.0 * g.m * g.k * g.n for g in self.gemms)


def _mk(name, desc, model, params, inp, out, req, gemms) -> Workload:
    return Workload(name, desc, model, params, inp, out, req, tuple(gemms))


WORKLOADS = {w.name: w for w in [
    _mk("credit_risk", "Loan approval risk scoring", "LogReg", 200,
        800, 4, 800, [fc(1, 200, 1, vec=200)]),
    _mk("asset_damage", "CCTV damage detection", "ResNet-50", 25e6,
        602112, 4000, 230400, resnet50_gemms()),
    _mk("ppe_detection", "Factory protective-gear detection", "YOLOv3", 65e6,
        2076672, 2759520, 614400, yolov3_gemms()),
    _mk("clinical", "Medical scan segmentation", "FCN", 54e6,
        602112, 602112, 230400, fcn_gemms()),
    _mk("content_moderation", "Offensive-content detection", "EfficientNet",
        11.5e6, 602112, 4000, 230400, efficientnet_b0_gemms()),
    _mk("chatbot", "Question answering", "BERT-Base", 110e6,
        393216, 393216, 2048, transformer_gemms(1, 128, 12, 768, 12, 3072)),
    _mk("translation", "Document translation", "GPT-2", 1.5e9,
        512, 512, 2048, transformer_gemms(1, 128, 48, 1600, 25, 6400, vocab=50257)),
    _mk("remote_sensing", "UAV traffic monitoring", "ViT", 632e6,
        602112, 4000, 230400, transformer_gemms(1, 257, 32, 1280, 16, 5120, vocab=1000)),
]}
