"""Multi-tenant DSA sharing: tenant model + pluggable drive schedulers.

The paper's §V scheduler dedicates each drive's 15 W DSA to one request at
a time (run-to-completion, no multi-tenancy) — which wastes
accelerator-seconds exactly when serverless multiplexing should shine, and
ROADMAP names "Multi-tenant DSAs" as the top open item.  This module is
the tenant-facing layer of that relaxation (cf. Hardless, arXiv
2208.03192, on shared serverless accelerator pools, and ServerMix, arXiv
1907.11465, on fairness/interference of multiplexed serverless resources):

  * :class:`TenantSpec` — one tenant's contract: its pipeline (workload)
    mix, its own arrival process (multiplexed deterministically by
    :class:`repro.core.arrivals.MergedArrivals`), an SLA target, and a
    share weight the drive schedulers honor.
  * :class:`DriveScheduler` policies — how a drive's DSA is shared between
    tenants.  Value objects; the engine implements the mechanics:

      - :class:`FCFSRunToCompletion` — the paper's baseline: one FCFS
        queue per drive, run-to-completion, tenants interleave
        arbitrarily (no isolation).
      - :class:`WeightedTimeSlice` — weighted round-robin time-slicing:
        each rotation serves the next backlogged tenant for a quantum of
        ``quantum_s * weight``, preempting the copy (its remaining service
        resumes at the tenant's next turn) and paying a modeled
        ``switch_s`` DSA context-switch cost whenever the serving tenant
        changes.
      - :class:`SpatialPartition` — the drive's DSA is split into
        ``lanes`` PE groups assigned to tenants in proportion to their
        weights (largest-remainder, at least one lane each).  Each
        tenant's lane group is an independent FCFS run-to-completion
        server whose service time is inflated by ``lanes/assigned`` —
        hard isolation at a per-request throughput cost.

  * fairness scoring — :func:`jain_index`,
    :func:`isolation_violation_rate` and per-tenant
    :func:`tenant_reports` over an :class:`~repro.core.engine.EngineTrace`
    (consumed duck-typed: this module never imports the engine).

``benchmarks/figures.py::fig21_tenant_fairness`` is the fairness study: a
bursty noisy-neighbor tenant degrading a latency-sensitive tenant's p99
under FCFS, with time-slicing/partitioning restoring isolation at a
quantified throughput cost.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.function import Pipeline

__all__ = [
    "DriveScheduler", "FCFSRunToCompletion", "SpatialPartition",
    "TenantReport", "TenantSpec", "WeightedTimeSlice", "assign_lanes",
    "isolation_violation_rate", "jain_index", "tenant_reports",
]


@dataclass(frozen=True)
class TenantSpec:
    """One tenant's contract with the shared fleet.

    ``pipelines`` is the tenant's workload mix (each request picks
    uniformly from it, like the single-tenant engine does over its
    pipeline list); ``arrivals`` is the tenant's own offered-load process,
    multiplexed with the other tenants' streams deterministically;
    ``sla_s`` is the per-tenant latency SLO that
    :func:`tenant_reports` scores attainment against; ``weight`` is the
    share the drive schedulers honor (quantum length under
    :class:`WeightedTimeSlice`, lane count under
    :class:`SpatialPartition`).
    """
    name: str
    pipelines: Tuple[Pipeline, ...]
    arrivals: ArrivalProcess
    sla_s: float = 0.6
    weight: float = 1.0

    def __post_init__(self) -> None:
        object.__setattr__(self, "pipelines", tuple(self.pipelines))
        if not self.pipelines:
            raise ValueError(f"tenant {self.name!r} needs at least one "
                             "pipeline in its mix")
        if self.sla_s <= 0.0:
            raise ValueError("sla_s must be positive")
        if self.weight <= 0.0:
            raise ValueError("weight must be positive")


# --------------------------------------------------------------------------
# drive schedulers (value objects; mechanics live in the engine loop)
# --------------------------------------------------------------------------

class DriveScheduler:
    """Base marker for drive-side DSA sharing policies.  Instances are
    immutable configuration; :meth:`repro.core.engine.ClusterEngine.run_soa`
    interprets them in its event loop."""
    name = "base"


@dataclass(frozen=True)
class FCFSRunToCompletion(DriveScheduler):
    """The paper's §V baseline: one FCFS queue per drive, run-to-
    completion, no DSA multi-tenancy.  Tenants share the queue with no
    isolation — a bursty neighbor heads-of-line-blocks everyone.  With a
    single default tenant this is bit-identical to the classic engine
    path (golden-trace gated)."""
    name = "fcfs"


@dataclass(frozen=True)
class WeightedTimeSlice(DriveScheduler):
    """Weighted round-robin time-slicing of a drive's DSA across tenants.

    Each scheduling decision serves the next backlogged tenant (cyclic
    order) for at most ``quantum_s * weight`` seconds; an unfinished copy
    is preempted and resumes (remaining service intact) at the tenant's
    next turn.  Whenever the serving tenant changes, the DSA pays
    ``switch_s`` of context-switch overhead (weight/scratchpad reload)
    before service resumes — the modeled cost that makes time-slicing a
    quantified throughput-vs-isolation tradeoff rather than a free lunch.
    """
    name = "timeslice"
    quantum_s: float = 0.02
    switch_s: float = 0.002

    def __post_init__(self) -> None:
        if self.quantum_s <= 0.0:
            raise ValueError("quantum_s must be positive")
        if self.switch_s < 0.0:
            raise ValueError("switch_s must be >= 0")


@dataclass(frozen=True)
class SpatialPartition(DriveScheduler):
    """Spatial partitioning of a drive's DSA PE array into lanes.

    ``lanes`` PE groups (0 = one lane per tenant) are assigned to tenants
    in proportion to their weights (largest remainder, at least one lane
    each — see :func:`assign_lanes`).  Each tenant's lane group on each
    drive is an independent FCFS run-to-completion server; a tenant
    holding ``l`` of ``L`` lanes runs every request ``L/l`` times slower
    (fewer PEs), which is the partitioning throughput cost.  Isolation is
    hard: a noisy neighbor cannot touch another tenant's lanes.
    """
    name = "spatial"
    lanes: int = 0

    def __post_init__(self) -> None:
        if self.lanes < 0:
            raise ValueError("lanes must be >= 0 (0 = one lane per tenant)")


def assign_lanes(weights: Sequence[float], lanes: int) -> List[int]:
    """Largest-remainder lane assignment with a one-lane floor per tenant.

    Deterministic: remainder ties break toward the lower tenant index.
    Raises if there are fewer lanes than tenants (every tenant must hold
    at least one lane or it could never be served).
    """
    k = len(weights)
    if lanes < k:
        raise ValueError(f"{lanes} lanes cannot cover {k} tenants "
                         "(every tenant needs at least one)")
    spare = lanes - k                   # one guaranteed lane each
    total_w = float(sum(weights))
    shares = [w / total_w * spare for w in weights]
    out = [1 + int(s) for s in shares]
    rem = [(-(s - int(s)), i) for i, s in enumerate(shares)]
    rem.sort()
    for j in range(spare - sum(int(s) for s in shares)):
        out[rem[j][1]] += 1
    return out


# --------------------------------------------------------------------------
# fairness scoring
# --------------------------------------------------------------------------

def jain_index(values: Sequence[float]) -> float:
    """Jain's fairness index ``(Σx)² / (n·Σx²)`` — 1.0 when every tenant
    gets an equal share, → 1/n when one tenant takes everything.  An
    empty or all-zero vector scores 1.0 (nothing to be unfair about)."""
    xs = np.asarray(values, dtype=float)
    if xs.size == 0:
        return 1.0
    sq = float(np.sum(xs * xs))
    if sq == 0.0:
        return 1.0
    s = float(np.sum(xs))
    return s * s / (xs.size * sq)


def isolation_violation_rate(shared_sla_frac: float,
                             solo_sla_frac: float) -> float:
    """How much SLA attainment a tenant *lost to its neighbors*: the drop
    from its solo-run attainment (same fleet, neighbors absent) to its
    attainment in the shared run, floored at zero (sharing can also help,
    e.g. via statistically multiplexed capacity)."""
    return max(0.0, float(solo_sla_frac) - float(shared_sla_frac))


@dataclass(frozen=True)
class TenantReport:
    """Per-tenant scorecard of one multi-tenant run."""
    name: str
    arrivals: int
    completions: int
    sla_s: float
    sla_met: int
    sla_frac: float
    p50_s: float
    p99_s: float
    mean_s: float
    busy_dscs_s: float                  # DSA service-seconds consumed
    busy_cpu_s: float                   # CPU service-seconds consumed
    max_queue_depth: float              # live queued copies, both classes
    mean_queue_depth: float             # time-averaged over the horizon


def tenant_reports(trace, tenants: Sequence[TenantSpec],
                   stats: Optional[Dict] = None) -> List[TenantReport]:
    """Score each tenant from an :class:`~repro.core.engine.EngineTrace`
    (duck-typed: needs ``.tenant``, ``.latency`` arrays) plus, optionally,
    the engine's :meth:`~repro.core.engine.ClusterEngine.tenant_stats`
    dict for the queue/busy-seconds columns (zeros when absent)."""
    tid = np.asarray(trace.tenant)
    lat = trace.latency
    out: List[TenantReport] = []
    for k, ten in enumerate(tenants):
        lk = lat[tid == k]
        n = int(lk.size)
        met = int(np.count_nonzero(lk <= ten.sla_s)) if n else 0
        if stats is not None:
            done = int(stats["completions"][k])
            busy_d = float(stats["busy_dscs_s"][k])
            busy_c = float(stats["busy_cpu_s"][k])
            maxd = float(max(stats["queue"]["dscs"]["max_depth"][k],
                             stats["queue"]["cpu"]["max_depth"][k]))
            meand = float(stats["queue"]["dscs"]["mean_depth"][k]
                          + stats["queue"]["cpu"]["mean_depth"][k])
        else:
            done = n                    # the engine drains every arrival
            busy_d = busy_c = maxd = meand = 0.0
        out.append(TenantReport(
            name=ten.name, arrivals=n, completions=done, sla_s=ten.sla_s,
            sla_met=met, sla_frac=met / n if n else 1.0,
            p50_s=float(np.percentile(lk, 50)) if n else 0.0,
            p99_s=float(np.percentile(lk, 99)) if n else 0.0,
            mean_s=float(np.mean(lk)) if n else 0.0,
            busy_dscs_s=busy_d, busy_cpu_s=busy_c,
            max_queue_depth=maxd, mean_queue_depth=meand))
    return out
