"""Discrete-event cluster engine (§V scheduler, §VI-C straggler study).

A genuine event-driven simulator of the extended Kubernetes scheduler from
the paper, rearchitected (PR 2) for million-request runs.  The simulation
semantics are unchanged from the PR-1 engine — the golden-trace tests pin
a bit-identical ``RequestResult`` stream seed-for-seed against the frozen
reference in :mod:`repro.core.engine_ref` — but the hot path is now
array-backed:

  * **batched event path** — per-request state lives in structure-of-arrays
    storage (numpy ``float64``/``int8`` arrays plus parallel Python lists
    for the per-event mutable codes), not per-request ``_Req``/``_Copy``
    objects.  Pipeline picks, acceleratability, placement hashes and
    service-quantile tail multipliers are pre-sampled in vectorized batches
    before/alongside the loop; the loop itself touches only plain tuples,
    ints and floats.
  * **streamed arrivals** — arrivals are consumed from the sorted arrival
    vector through an index cursor (materialized to Python floats in
    64K-request chunks), so the event heap holds only O(in-flight) events
    instead of O(total requests).  Hedge timers all share one constant
    budget, so they fire in arrival order and live in a FIFO deque rather
    than the heap — the heap holds only the finish events of currently
    running copies (at most one per server).  Ties between an arrival and
    a dynamic event break toward the arrival, exactly like the PR-1 global
    event sequence numbers did.
  * **O(1) queues** — each server's FCFS queue is a ``deque``; hedged-loser
    cancellation tombstones the copy in place (state flip) instead of an
    O(n) ``list.remove``, and the dispatch loop discards tombstones when
    they surface at the head.  A tombstoned copy is never started (asserted
    in the dispatch loop and counted in ``tombstones_discarded``).
  * **indexed CPU load heap** — the least-loaded CPU pick is a lazy
    ``(load, index)`` heap with stale-entry invalidation instead of an
    O(n_cpu) scan; ties still break toward the lowest node index.
  * **event model** — three event kinds, exactly as before:
      - ``arrival``  — a request enters (times from a pluggable
        :mod:`repro.core.arrivals` process)
      - ``finish``   — a running copy completes service on its node
      - ``hedge``    — the hedge timer for a queued acceleratable request
        expires
  * **data-aware placement** — each acceleratable request's payload lands
    on the ``Acceleratable_Storage`` drive its key hashes to (the same
    SHA-1 spread :class:`repro.core.placement.StoragePool` computes) and
    the request is dispatched to the drive that *holds* it.  Per-drive
    FCFS, run-to-completion, no DSA multi-tenancy (§V), with
    time-weighted queue-depth telemetry finalized to a common end-of-run
    horizon.
  * **real hedged dispatch** — if an acceleratable request is still queued
    ``hedge_budget_s`` after arrival, a second copy is issued on the
    least-loaded CPU node.  Both copies race; the first finisher wins and
    the loser is cancelled: a still-queued loser is tombstoned (consumes
    no service), while an already-running loser runs to completion
    occupying its node (run-to-completion — no preemption) and its result
    is discarded.  ``RequestResult`` records ``hedged``, ``winner`` and
    both finish times so tail-latency attribution (Fig. 16) is observable.

Every stochastic choice — pipeline sampling, service-time tails (drawn by
quantile inversion through ``LatencyModel.e2e(q=u)``) and the arrival
stream — derives from the single engine seed, so a run is exactly
reproducible and two engines with equal seeds emit identical
``RequestResult`` streams.  ``run()`` returns the historical
``List[RequestResult]``; ``run_soa()`` returns the native
:class:`EngineTrace` structure-of-arrays view (what
``benchmarks/bench_engine.py`` measures), and :class:`SampleBank` lets
repeated runs share one sampling pass (common random numbers for the
throughput binary search).

Autoscaling (PR 3): ``run_soa(..., controller=...)`` steps a control loop
at fixed epoch boundaries — the controller reads a :class:`FleetSnapshot`
of the engine's live queue/utilization telemetry and resizes the active
CPU subset and the powered drive set (powered-off drives wake with a
modeled ``dscs_wake_s`` latency).  ``power_stats()`` reports busy/powered
server-seconds for the energy/cost evaluation in
:mod:`repro.core.autoscale`.  Without a controller every hook is inert and
the event stream stays bit-identical to the PR-2 engine.

Multi-tenant DSA sharing (PR 4): ``run_soa(tenants=[...], scheduler=...)``
runs several :class:`~repro.core.tenancy.TenantSpec` streams — each with
its own pipeline mix, arrival process, SLA target and share weight —
through one fleet.  Arrival streams are multiplexed deterministically
(:class:`~repro.core.arrivals.MergedArrivals`), every request carries its
tenant id through the SoA columns (``EngineTrace.tenant``), and the
drive-side scheduling policy is pluggable:

  * :class:`~repro.core.tenancy.FCFSRunToCompletion` (default) — the
    paper's single-queue run-to-completion drives; with one default
    tenant this path is bit-identical to the classic engine (the
    golden-trace gates pin it).
  * :class:`~repro.core.tenancy.WeightedTimeSlice` — weighted round-robin
    quanta per tenant with preempt/resume and a modeled DSA
    context-switch cost.
  * :class:`~repro.core.tenancy.SpatialPartition` — per-tenant DSA lane
    groups (independent FCFS sub-servers, service inflated by the lane
    fraction).

Per-tenant telemetry (arrivals, completions, busy service-seconds,
time-weighted queue depths finalized to the common horizon) comes back
through :meth:`ClusterEngine.tenant_stats`, and :class:`FleetSnapshot`
exposes per-tenant live views so autoscaling policies can scale on the
worst-off tenant.  ``preempt_losers=True`` additionally cancels hedge
losers *in service* (the classic engine only discards never-started
tombstones), counting the reclaimed server-seconds in telemetry.

Tiered data layer (PR 5): ``ClusterEngine(tier=TierConfig(...))`` swaps
the memoized single-hash placement for cache-warmth- and load-aware
routing over each object's k-way replica set (``drive_l`` becomes the
replica-choice column), models per-drive DRAM caches (hits shave the
flash-P2P + NS-driver time off the service draw), lazily materializes
secondary replicas from a remote backing store, and lets a
:class:`~repro.core.tiering.MigrationController` retarget Zipf-hot keys
off saturated drives at its own epoch boundaries.  Telemetry lands in
:meth:`ClusterEngine.tier_stats`.  A ``None``/disabled tier takes the
classic path — same rng spawns, no extra draws — so tier-off runs stay
bit-identical to the golden traces.  The tier composes with autoscaling
and with multi-tenant FCFS; time-sliced/partitioned DSAs raise.
"""
from __future__ import annotations

import hashlib
import heapq
import math
from array import array
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.arrivals import ArrivalProcess, MergedArrivals
from repro.core.faults import (CPU_CRASH, CPU_RECOVER, DRIVE_FAIL,
                               DRIVE_RECOVER, STALL_BEGIN, STALL_END,
                               FaultPlan)
from repro.core.function import Pipeline, is_acceleratable
from repro.core.latency import LatencyModel, _erfinv
from repro.core.overload import AdmitAll, OverloadControl, QueueThreshold, \
    TokenBucket
from repro.core.platforms import (CPU_FALLBACK_PLATFORM, DSCS_PLATFORM,
                                  PLATFORMS)
from repro.core.tenancy import (FCFSRunToCompletion, SpatialPartition,
                                TenantSpec, WeightedTimeSlice, assign_lanes)
from repro.core.tiering import (DriveCache, MigrationController, TierConfig,
                                _hrw_ranking, build_replica_table,
                                zipf_object_ids)
from repro.core.workloads import Workload


@dataclass
class Telemetry:
    """Prometheus-analogue counters (shared with the scheduler façade)."""
    counters: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    def inc(self, name: str, v: float = 1.0) -> None:
        """Add ``v`` to counter ``name`` (created at zero on first use)."""
        self.counters[name] += v

    def get(self, name: str) -> float:
        """Current value of counter ``name`` (zero if never incremented)."""
        return self.counters[name]


def _erfinv_vec(x: np.ndarray) -> np.ndarray:
    """Vectorized Winitzki approximation — same formula as
    :func:`repro.core.latency._erfinv`, batched through numpy."""
    a = 0.147
    ln = np.log(1.0 - x * x)
    t = 2.0 / (math.pi * a) + ln / 2.0
    return np.copysign(np.sqrt(np.sqrt(t * t - ln / a) - t), x)


class _ServiceSampler:
    """Chunked, vectorized service-time sampler by quantile inversion.

    ``LatencyModel.pipeline_breakdown`` at quantile ``q`` decomposes as
    ``A + R*Tr(q) + W*Tw(q)`` — a deterministic part plus the summed
    network-read/-write bases scaled by their shared lognormal quantile
    multipliers.  Solving that 3x3 system once per (workload, platform)
    turns every per-request draw into one fused multiply-add over
    pre-transformed tail multipliers.

    Uniform draws are taken from the engine rng in chunks of ``chunk`` and
    pushed through the erfinv/exp transform in one vectorized batch, then
    consumed one value per service start — the consumption *order* is the
    engine's event order, so two engines that process events identically
    draw identical values.  ``numpy``'s vectorized chunk draw consumes the
    PCG64 stream exactly like per-call scalar draws, and because both the
    optimized and the frozen reference engine share this sampler, their
    streams are bit-identical regardless of the host's libm/SIMD exp.

    Modeling note: a single uniform draw ``u`` drives every tail multiplier
    of a request comonotonically (all reads and writes are slow together),
    whereas the pre-engine scheduler sampled each network component
    independently.  The comonotone total has a somewhat fatter tail than
    the independent sum, so absolute p99/SLA numbers shift slightly versus
    the seed model; within-experiment comparisons (hedging on/off, arrival
    shapes, fleet ratios) are unaffected.
    """

    def __init__(self, lm: LatencyModel, chunk: int = 4096,
                 persistent: bool = False):
        self.lm = lm
        self.chunk = chunk
        self.persistent = persistent        # keep draws across start() calls
        self._coef: Dict[tuple, Tuple[float, float, float]] = {}
        self._rng: Optional[np.random.Generator] = None
        self._tr: List[float] = []
        self._tw: List[float] = []
        self._i = 0

    # -- coefficient fitting (deterministic, no rng) -------------------------
    def _tails(self, q: float) -> tuple:
        z = math.sqrt(2.0) * _erfinv(2.0 * q - 1.0)
        return (math.exp(self.lm.params.read_sigma * z),
                math.exp(self.lm.params.write_sigma * z))

    def coef(self, workload: Workload, platform: str) -> Tuple[float, float, float]:
        # service time depends only on (workload, platform); Workload is a
        # frozen dataclass, so this key is stable (unlike id()) and shared
        # across pipeline variants of the same workload
        key = (workload, platform)
        c = self._coef.get(key)
        if c is None:
            plat = PLATFORMS[platform]
            qs = (0.5, 0.84, 0.975)
            rows = [(1.0,) + self._tails(q) for q in qs]
            e2e = [self.lm.e2e(plat, workload, q=q) for q in qs]
            # lstsq, not solve: with read_sigma == write_sigma the Tr and Tw
            # columns coincide and the system is rank-2; the minimum-norm
            # solution still reproduces e2e(q) exactly
            sol = np.linalg.lstsq(np.array(rows), np.array(e2e), rcond=None)[0]
            c = (float(sol[0]), float(sol[1]), float(sol[2]))
            self._coef[key] = c
        return c

    # -- draw stream ---------------------------------------------------------
    def start(self, rng: np.random.Generator) -> None:
        """Bind the per-run rng and reset the draw cursor (persistent
        samplers keep their already-transformed draws)."""
        self._rng = rng
        self._i = 0
        if not self.persistent:
            self._tr = []
            self._tw = []

    def rewind(self) -> None:
        """Replay the cached draw stream from the top (common random
        numbers across runs)."""
        self._i = 0

    def _grow(self) -> None:
        u = self._rng.uniform(size=self.chunk)
        np.clip(u, 1e-4, 1.0 - 1e-4, out=u)
        z = math.sqrt(2.0) * _erfinv_vec(2.0 * u - 1.0)
        self._tr.extend(np.exp(self.lm.params.read_sigma * z).tolist())
        self._tw.extend(np.exp(self.lm.params.write_sigma * z).tolist())

    def draw(self, coef: Tuple[float, float, float]) -> float:
        """One service time: the next cached tail pair through the
        (workload, platform) coefficients."""
        i = self._i
        if i == len(self._tr):
            self._grow()
        self._i = i + 1
        return coef[0] + coef[1] * self._tr[i] + coef[2] * self._tw[i]


@dataclass(frozen=True)
class FleetSnapshot:
    """What an autoscaling controller sees at one epoch boundary.

    Built from the engine's own live telemetry — queue depths exclude
    tombstoned (cancelled-in-queue) copies, busy counts are servers with a
    copy in service, and ``arrivals``/``completions`` are deltas since the
    previous epoch.  ``n_cpu_active`` / ``n_dscs_on`` are the *powered*
    capacity the previous actions produced (waking drives count as on);
    ``n_cpu_total`` / ``n_dscs_total`` are the provisioned maxima the
    controller may scale within.
    """
    time: float                         # epoch boundary (simulated seconds)
    epoch: int                          # 1-based epoch index
    arrivals: int                       # arrivals since the previous epoch
    completions: int                    # requests completed since then
    dscs_queue: int                     # live queued DSCS copies, fleet-wide
    cpu_queue: int                      # live queued CPU copies, fleet-wide
    dscs_busy: int                      # drives with a copy in service
    cpu_busy: int                       # CPU nodes with a copy in service
    n_cpu_active: int                   # nodes eligible for new dispatch
    n_dscs_on: int                      # powered (on or waking) drives
    n_cpu_total: int
    n_dscs_total: int
    # per-tenant views (empty tuples on single-tenant runs): live queued
    # copies fleet-wide (both classes) and arrival/completion deltas since
    # the previous epoch, indexed by tenant — so a policy can scale on the
    # worst-off tenant instead of the fleet aggregate.
    tenant_queue: Tuple[int, ...] = ()
    tenant_arrivals: Tuple[int, ...] = ()
    tenant_completions: Tuple[int, ...] = ()
    # overload-control signals (zero/neutral without an OverloadControl):
    # arrivals rejected / requests shed since the previous epoch, and the
    # pushback factor currently applied to the arrival sources — so a
    # policy can scale out on rejection pressure before queues even grow.
    rejected: int = 0
    shed: int = 0
    pushback: float = 1.0


@dataclass
class RequestResult:
    """One completed request.  ``finish``/``accelerated`` describe the
    winning copy; for hedged requests both per-path finish times are kept
    (the loser's is back-filled when its run-to-completion copy drains, and
    stays ``None`` if it was cancelled while still queued)."""
    arrival: float
    finish: float
    accelerated: bool
    hedged: bool = False
    winner: str = ""                    # "dscs" | "cpu"
    drive: int = -1                     # serving DSCS drive index, -1 = CPU
    start: float = 0.0                  # winning copy's service start
    service: float = 0.0                # winning copy's service duration
    dscs_finish: Optional[float] = None
    cpu_finish: Optional[float] = None
    tenant: int = 0                     # owning tenant (0 on single-tenant)

    @property
    def latency(self) -> float:
        """End-to-end latency of the winning copy (finish - arrival)."""
        return self.finish - self.arrival

    @property
    def queue_wait(self) -> float:
        """Time the winning copy spent queued before service began."""
        return self.start - self.arrival


@dataclass
class EngineTrace:
    """Structure-of-arrays view of one run — the engine's native output.

    One slot per arrival, in arrival order.  ``winner`` is 0 for the DSCS
    path, 1 for the CPU path, -1 for requests abandoned by a fault-retry
    exhaustion or a ``timeout_s`` deadline (their ``finish`` is NaN);
    ``drive`` is the serving DSCS drive index or
    -1 for CPU-served requests; ``dscs_finish``/``cpu_finish`` are NaN
    where the path never completed (maps to ``None`` in
    :class:`RequestResult`).  ``to_results()`` materializes the historical
    object stream; large sweeps should consume the arrays directly.
    """
    arrival: np.ndarray                 # float64 arrival times
    finish: np.ndarray                  # float64 winning-copy finish
    winner: np.ndarray                  # int8: 0 = dscs, 1 = cpu
    drive: np.ndarray                   # int32 serving drive or -1
    start: np.ndarray                   # float64 winning-copy service start
    service: np.ndarray                 # float64 winning-copy service time
    hedged: np.ndarray                  # bool
    dscs_finish: np.ndarray             # float64, NaN = path never finished
    cpu_finish: np.ndarray              # float64, NaN = path never finished
    events: int = 0                     # events processed (incl. arrivals)
    tenant: Optional[np.ndarray] = None  # int32 tenant ids (zeros if 1-tenant)

    @property
    def n(self) -> int:
        """Number of requests in the trace (= arrivals simulated)."""
        return int(self.arrival.size)

    @property
    def latency(self) -> np.ndarray:
        """Per-request end-to-end latency vector (finish - arrival).
        NaN for requests abandoned by faults or deadlines."""
        return self.finish - self.arrival

    @property
    def completed(self) -> np.ndarray:
        """Boolean mask of requests that finished (fault/deadline
        abandonments have NaN finish and winner -1)."""
        return ~np.isnan(self.finish)

    def to_results(self) -> List[RequestResult]:
        isnan = math.isnan
        arr, fin = self.arrival.tolist(), self.finish.tolist()
        win, drv = self.winner.tolist(), self.drive.tolist()
        st, sv = self.start.tolist(), self.service.tolist()
        hg = self.hedged.tolist()
        df, cf = self.dscs_finish.tolist(), self.cpu_finish.tolist()
        tn = (self.tenant.tolist() if self.tenant is not None
              else [0] * len(arr))
        out = []
        for i in range(len(arr)):
            w = win[i]
            out.append(RequestResult(
                arrival=arr[i], finish=fin[i], accelerated=w == 0,
                hedged=hg[i],
                winner="dscs" if w == 0 else ("cpu" if w == 1 else ""),
                drive=drv[i], start=st[i], service=sv[i],
                dscs_finish=None if isnan(df[i]) else df[i],
                cpu_finish=None if isnan(cf[i]) else cf[i],
                tenant=tn[i]))
        return out


class SampleBank:
    """Common-random-numbers cache shared across engine runs.

    The throughput binary search probes the same fleet at many rates; with
    a bank, pipeline picks and service-tail draws are sampled once (grown
    on demand, never redrawn) and replayed by every probe, so the whole
    search costs one sampling pass and probes differ only through the
    offered load — the classic variance-reduction setup that also makes
    ``max_throughput`` monotone-friendly in fleet size.

    The bank draws from dedicated SeedSequence children (2, 3) of the
    engine seed, so banked runs are reproducible but statistically
    independent of the engine's own (0, 1) arrival/service streams.
    """

    def __init__(self, engine: "ClusterEngine", pipelines: Sequence[Pipeline]):
        kids = np.random.SeedSequence(engine.seed).spawn(4)
        self._pick_rng = np.random.default_rng(kids[2])
        self._n_pipes = len(pipelines)
        self._picks = np.empty(0, dtype=np.int64)
        self.tails = _ServiceSampler(engine.lm, persistent=True)
        self.tails.start(np.random.default_rng(kids[3]))

    def picks(self, n: int) -> np.ndarray:
        """The first ``n`` pipeline picks (a prefix of one fixed stream)."""
        if n > self._picks.size:
            grow = max(n - self._picks.size, self._picks.size, 1024)
            self._picks = np.concatenate(
                [self._picks, self._pick_rng.integers(self._n_pipes, size=grow)])
        return self._picks[:n]


# copy states (per path, per request).  _PREEMPTED marks a cancelled copy
# whose server was already freed (preemptive loser cancellation / dropped
# time-slice segment): any stale heap event for it is skipped on pop.
_FREE, _QUEUED, _RUNNING, _DONE, _CANCELLED, _PREEMPTED = 0, 1, 2, 3, 4, 5
_CHUNK = 1 << 16                        # arrival-streaming chunk

# Memoized data-aware placement: drive index for request id i is
# SHA-1("req-i") mod the Acceleratable_Storage drive count — exactly the
# spread StoragePool.place computes.  Placement is deterministic, so the
# table is shared by every run and throughput probe with the same fleet.
_PLACEMENT_CACHE: Dict[int, np.ndarray] = {}


def _placement(n_dscs: int, n: int) -> np.ndarray:
    arr = _PLACEMENT_CACHE.get(n_dscs)
    if arr is None or arr.size < n:
        start = 0 if arr is None else int(arr.size)
        size = max(n, 2 * start, 1024)
        sha1 = hashlib.sha1
        grown = np.empty(size, dtype=np.int32)
        if start:
            grown[:start] = arr
        nd = np.uint64(n_dscs)
        # digests are joined and Horner-reduced in bounded chunks so the
        # transient digest buffer stays a few MB at any request count;
        # acc < n_dscs <= 2^31 keeps the uint64 intermediate
        # (acc << 32) + word exact, so the result is bit-identical to
        # int.from_bytes(digest, "big") % n_dscs
        for c0 in range(start, size, _CHUNK):
            c1 = min(c0 + _CHUNK, size)
            buf = b"".join([sha1(b"req-%d" % i).digest()
                            for i in range(c0, c1)])
            words = np.frombuffer(buf, dtype=">u4").reshape(-1, 5) \
                .astype(np.uint64)
            acc = words[:, 0] % nd
            for j in range(1, 5):
                acc = ((acc << np.uint64(32)) + words[:, j]) % nd
            grown[c0:c1] = acc
        _PLACEMENT_CACHE[n_dscs] = arr = grown
    return arr[:n]


class ClusterEngine:
    """The discrete-event fleet: ``n_dscs`` DSCS drives with per-drive FCFS
    queues + ``n_cpu`` CPU fallback nodes, fed by an arrival process."""

    def __init__(self, *, n_dscs: int, n_cpu: int,
                 latency_model: Optional[LatencyModel] = None,
                 hedge_budget_s: Optional[float] = None, seed: int = 0,
                 n_plain: int = 64,
                 telemetry: Optional[Telemetry] = None,
                 dscs_wake_s: float = 0.2,
                 preempt_losers: bool = False,
                 tier: Optional[TierConfig] = None,
                 faults: Optional[FaultPlan] = None,
                 overload: Optional[OverloadControl] = None):
        if n_cpu <= 0:
            raise ValueError("the fleet needs at least one CPU fallback node")
        self.n_dscs = n_dscs
        self.n_cpu = n_cpu
        self.n_plain = n_plain
        self.lm = latency_model or LatencyModel(seed=seed)
        self.hedge_budget_s = hedge_budget_s
        self.seed = seed
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.dscs_wake_s = dscs_wake_s  # powered-off drive wake-up latency
        # preemptive loser cancellation: when True, a hedge loser caught
        # *in service* is cancelled immediately (its server is freed and
        # the reclaimed service-seconds are counted in telemetry) instead
        # of draining run-to-completion.  Default False = the paper's §V
        # run-to-completion semantics (golden-trace gated).
        self.preempt_losers = preempt_losers
        # tiered data layer (tiering.py): per-drive DRAM caches, k-way
        # replica routing, lazy backing-store fills and hot-key migration.
        # None or a disabled config keeps the classic bit-exact path.
        self.tier = tier
        if tier is not None:
            tier.validate()
        # fault injection & recovery (faults.py): seeded drive/CPU failure
        # processes, retry-with-backoff re-dispatch, replica repair and
        # timeout-based failure detection.  None keeps the classic
        # bit-exact path (no extra SeedSequence child is even spawned).
        self.faults = faults
        if faults is not None:
            faults.validate()
        # overload control (overload.py): admission, queue shedding,
        # backpressure and brownout.  Every policy is a deterministic
        # function of engine state — the layer draws no randomness, spawns
        # no SeedSequence child, and None (or a config with every
        # mechanism off) keeps the classic bit-exact path.
        self.overload = overload
        if overload is not None:
            overload.validate()
        self._sampler = _ServiceSampler(self.lm)
        self._qstate: Optional[dict] = None
        self.last_shard_stats: Optional[dict] = None
        self._pstate: Optional[dict] = None
        self._tstate: Optional[dict] = None
        self._tierstate: Optional[dict] = None
        self._fstate: Optional[dict] = None
        self._ovstate: Optional[dict] = None

    def sample_bank(self, pipelines: Sequence[Pipeline]) -> SampleBank:
        """A :class:`SampleBank` for common-random-number runs."""
        return SampleBank(self, pipelines)

    # -- public API ----------------------------------------------------------
    def run(self, pipelines: List[Pipeline], *, arrivals: ArrivalProcess,
            duration_s: float,
            timeout_s: Optional[float] = None) -> List[RequestResult]:
        """Simulate ``duration_s`` of offered load and drain every request;
        returns one ``RequestResult`` per arrival, in arrival order."""
        return self.run_soa(pipelines, arrivals=arrivals,
                            duration_s=duration_s,
                            timeout_s=timeout_s).to_results()

    def run_soa(self, pipelines: Optional[Sequence[Pipeline]] = None, *,
                arrivals: Optional[ArrivalProcess] = None,
                duration_s: float = 0.0,
                times: Optional[np.ndarray] = None,
                bank: Optional[SampleBank] = None,
                controller=None,
                tenants: Optional[Sequence[TenantSpec]] = None,
                scheduler=None,
                timeout_s: Optional[float] = None,
                overload: Optional[OverloadControl] = None) -> EngineTrace:
        """The batched event loop; returns the run as an
        :class:`EngineTrace`.

        ``times`` (a sorted arrival-time vector) overrides ``arrivals``;
        ``bank`` replays pre-sampled picks/service draws instead of the
        engine's own seed-derived streams (common random numbers).

        ``controller`` attaches an autoscaling control loop (see
        :mod:`repro.core.autoscale`): an object with an ``epoch_s`` period
        and an ``observe(snapshot) -> action`` method.  At every epoch
        boundary the engine hands it a :class:`FleetSnapshot` and applies
        the returned action — resizing the *active* CPU subset (deactivated
        nodes drain run-to-completion, then power off) and powering DSCS
        drives up/down (a powered-off drive woken by an arrival, or
        proactively by the controller, serves only after ``dscs_wake_s``).
        Epoch boundaries fire before same-time dynamic events but after
        same-time arrivals, and stop once the fleet has fully drained.
        With ``controller=None`` none of this machinery runs and the event
        stream is bit-identical to the pre-autoscaling engine (the
        golden-trace gates pin this).

        ``tenants`` switches the run to multi-tenant mode: each
        :class:`~repro.core.tenancy.TenantSpec` brings its own pipeline
        mix and arrival process (multiplexed deterministically, each
        stream drawn from its own child generator), and every request
        carries its tenant id (``EngineTrace.tenant``).  ``scheduler``
        picks how drives share their DSA between tenants —
        :class:`~repro.core.tenancy.FCFSRunToCompletion` (default, and
        with one tenant bit-identical to the classic path),
        :class:`~repro.core.tenancy.WeightedTimeSlice` (weighted quanta,
        preempt/resume, modeled context-switch cost; a preempted copy's
        ``start``/``service`` record its first service start and total
        service demand, so ``finish > start + service`` when segments are
        interleaved), or :class:`~repro.core.tenancy.SpatialPartition`
        (per-tenant lane groups with proportionally inflated service).
        Per-tenant telemetry lands in :meth:`tenant_stats`.  The CPU
        fallback pool stays least-loaded/FCFS in every mode.

        ``overload`` attaches the overload-control layer
        (:class:`~repro.core.overload.OverloadControl`: admission control,
        queue shedding, backpressure, brownout), overriding the engine-
        level config for this run; telemetry lands in
        :meth:`overload_stats`.  The layer is rng-free — ``None`` or a
        fully-disabled config keeps the classic bit-exact event stream.
        """
        mt = tenants is not None
        sk = 0                          # 0 fcfs | 1 timeslice | 2 spatial
        sched = scheduler
        if not mt:
            if scheduler is not None:
                raise ValueError("scheduler= requires tenants= (single-"
                                 "tenant runs always use per-drive FCFS)")
            if pipelines is None:
                raise ValueError("pass pipelines= (or tenants=)")
        else:
            tenants = list(tenants)
            if not tenants:
                raise ValueError("tenants= must name at least one tenant")
            if pipelines is not None:
                raise ValueError("with tenants=, pipelines come from each "
                                 "TenantSpec's mix; drop the pipelines "
                                 "argument")
            if times is not None or arrivals is not None:
                raise ValueError("with tenants=, arrivals come from each "
                                 "TenantSpec; pass neither times= nor "
                                 "arrivals=")
            if bank is not None:
                raise ValueError("SampleBank CRN replay is single-tenant "
                                 "only")
            if duration_s <= 0.0:
                raise ValueError("tenants= needs a positive duration_s")
            if sched is None:
                sched = FCFSRunToCompletion()
            if isinstance(sched, WeightedTimeSlice):
                sk = 1
            elif isinstance(sched, SpatialPartition):
                sk = 2
            elif isinstance(sched, FCFSRunToCompletion):
                sk = 0
            else:
                raise TypeError(f"unknown drive scheduler: {sched!r}")
            if controller is not None and sk != 0:
                raise NotImplementedError(
                    "autoscaling composes with the FCFS drive scheduler "
                    "only; time-sliced/partitioned DSAs with power "
                    "cycling are future work")

        tier = self.tier
        tier_on = tier is not None and tier.enabled
        if tier_on:
            if sk != 0:
                raise NotImplementedError(
                    "the tiered data layer composes with the FCFS drive "
                    "scheduler only; cache/replica routing under time-"
                    "sliced or partitioned DSAs is future work")
            if self.n_dscs < 1:
                raise ValueError("the tiered data layer needs n_dscs >= 1")
        self._tierstate = None
        self._fstate = None
        self._ovstate = None

        fp = self.faults
        fa = fp is not None
        if fa and mt:
            raise NotImplementedError(
                "fault injection composes with single-tenant runs only; "
                "lost-copy accounting under multi-tenant schedulers is "
                "future work")
        if timeout_s is not None:
            if timeout_s <= 0.0:
                raise ValueError("timeout_s must be positive")
            if mt:
                raise NotImplementedError(
                    "timeout_s deadlines compose with single-tenant "
                    "runs only")

        # overload control: a run_soa override falls back to the engine-
        # level config (like tier/faults).  Enabled means at least one of
        # admission / shedding / backpressure / brownout is active; the
        # layer is rng-free, so no SeedSequence child is spawned either way
        ov = overload if overload is not None else self.overload
        ov_on = ov is not None and ov.enabled
        if ov_on:
            ov.validate()
            if sk != 0:
                raise NotImplementedError(
                    "overload control composes with the FCFS drive "
                    "scheduler only; queue shedding under time-sliced or "
                    "partitioned DSAs is future work")

        ss = np.random.SeedSequence(self.seed)
        # SeedSequence children are keyed by index, so earlier children are
        # identical regardless of how many later ones (tier, faults) are
        # spawned — fault-free tier-off runs keep the exact golden-trace
        # streams
        kids = ss.spawn(4 if fa else (3 if tier_on else 2))
        arr_rng, rng = (np.random.default_rng(s) for s in kids[:2])
        tier_rng = np.random.default_rng(kids[2]) if tier_on else None
        frng = np.random.default_rng(kids[3]) if fa else None
        src: Optional[np.ndarray] = None
        if mt:
            merged = MergedArrivals(
                processes=tuple(t.arrivals for t in tenants))
            times, src = merged.times_and_sources(duration_s, arr_rng)
        elif times is None:
            if arrivals is None:
                raise ValueError("pass arrivals= or times=")
            if duration_s <= 0.0:
                raise ValueError("arrivals= needs a positive duration_s "
                                 "(an empty window would silently simulate "
                                 "zero requests)")
            times = arrivals.times(duration_s, arr_rng)
        times = np.ascontiguousarray(np.asarray(times, dtype=np.float64))
        n = int(times.size)

        if mt:
            # the combined pipeline list concatenates each tenant's mix;
            # per-request picks index the owning tenant's slice (drawn in
            # tenant order, so the stream is deterministic per seed)
            pipelines = [p for t in tenants for p in t.pipelines]
            picks = np.empty(n, dtype=np.int64)
            off = 0
            for k, ten in enumerate(tenants):
                mask = src == k
                picks[mask] = off + rng.integers(
                    len(ten.pipelines), size=int(np.count_nonzero(mask)))
                off += len(ten.pipelines)
            sampler = self._sampler
            sampler.start(rng)
        elif bank is not None:
            picks = bank.picks(n)
            sampler = bank.tails
            sampler.rewind()
        else:
            picks = (rng.integers(len(pipelines), size=n) if n
                     else np.empty(0, dtype=np.int64))
            sampler = self._sampler
            sampler.start(rng)

        # -- vectorized pre-sampling ----------------------------------------
        nd, nc = self.n_dscs, self.n_cpu
        coef_d = [sampler.coef(p.workload, DSCS_PLATFORM) for p in pipelines]
        coef_c = [sampler.coef(p.workload, CPU_FALLBACK_PLATFORM)
                  for p in pipelines]
        accel_pipe = np.array(
            [nd > 0 and is_acceleratable(p) for p in pipelines], dtype=bool)
        picks_l = picks.tolist()
        accel_l = (accel_pipe[picks].tolist() if n else [])
        if nd and n and not tier_on:
            drive_l = _placement(nd, n).tolist()
        else:
            # tier on: drive_l is the replica-choice column, written at
            # arrival time by the replica router below (-1 until routed)
            drive_l = [-1] * n

        # -- per-request SoA state ------------------------------------------
        ds_l = [0] * n                  # DSCS-copy state codes
        cs_l = [0] * n                  # CPU-copy state codes
        c_node_l = [-1] * n
        hedged_l = [False] * n
        winner_l = [-1] * n
        nan = math.nan
        finish_a = array("d", [nan]) * n
        dfin_a = array("d", [nan]) * n
        cfin_a = array("d", [nan]) * n
        d_start_a = array("d", bytes(8 * n))
        d_svc_a = array("d", bytes(8 * n))
        c_start_a = array("d", bytes(8 * n))
        c_svc_a = array("d", bytes(8 * n))

        # -- per-server state ------------------------------------------------
        d_queues = [deque() for _ in range(nd)]
        c_queues = [deque() for _ in range(nc)]
        d_busy = [0] * nd; c_busy = [0] * nc
        d_qd = [0] * nd; c_qd = [0] * nc        # live queued (no tombstones)
        d_area = [0.0] * nd; c_area = [0.0] * nc
        d_last = [0.0] * nd; c_last = [0.0] * nc
        d_maxd = [0] * nd; c_maxd = [0] * nc
        c_load = [0] * nc
        loadheap = [(0, i) for i in range(nc)]  # sorted => already a heap

        hpush, hpop = heapq.heappush, heapq.heappop
        INF = math.inf
        NAN = math.nan
        hedge = self.hedge_budget_s
        heap: List[tuple] = []          # (time, (rid << 1) | path), or
                                        # (time, -(drive + 1)) wake events
        hedge_dq: deque = deque()       # (time, rid): FIFO, arrival order
        end_t = 0.0                     # time of the last completion
        # the sampler's chunked draw stream, inlined: _grow() extends the
        # tr/tw lists in place, so these aliases stay valid across refills
        s_tr = sampler._tr; s_tw = sampler._tw
        s_grow = sampler._grow
        s_i = sampler._i
        # telemetry accumulators (flushed once at the end)
        t_ddisp = t_cdisp = t_hedge = 0
        t_won_d = t_won_c = t_srv_d = t_srv_c = 0
        t_can_q = t_can_s = t_tomb = 0
        d_busy_s = c_busy_s = 0.0       # service-seconds per class
        preempt = self.preempt_losers
        rec_d = rec_c = 0.0             # reclaimed service-seconds per class
        t_switch_s = 0.0                # time-slice context-switch overhead
        t_pre = 0                       # quantum-expiry events processed

        # -- tiered data-layer state (tiering.py) ----------------------------
        # Replica routing replaces the memoized single-hash placement:
        # drive_l becomes the replica-choice column of the SoA state,
        # written per arrival from the object's replica set.
        t_fill = 0                      # backing-store fetches (lazy fills)
        fill_s = 0.0                    # backing-fetch seconds added
        mig = None
        mig_t = INF                     # next migration epoch boundary
        if tier_on:
            t_k = min(tier.replication_k, nd)
            t_nobj = tier.n_objects
            t_objbytes = tier.object_bytes
            rb = [p.workload.request_bytes for p in pipelines]
            if t_nobj:
                obj_l = zipf_object_ids(n, t_nobj, tier.zipf_s,
                                        tier_rng).tolist()
                replicas = build_replica_table(t_nobj, nd, t_k)
            else:
                # one unique object per request: replica sets computed
                # lazily at arrival (object id = request id)
                obj_l = None
                replicas = {}
            # primary copies are durably materialized up front; secondary
            # and migrated-to drives fill lazily from the backing store
            mat = [set() for _ in range(nd)]
            if t_nobj:
                for o2, r2 in enumerate(replicas):
                    mat[r2[0]].add(o2)
            caches = ([DriveCache(tier.cache_bytes, tier.admit_after)
                       for _ in range(nd)]
                      if tier.cache_bytes > 0 else None)
            if tier.migration is not None:
                mig = MigrationController(tier.migration)
                mig_s = tier.migration.epoch_s
                mig_t = mig_s
                acc = [dict() for _ in range(nd)]  # per-drive obj hits/epoch

        # -- per-tenant state (multi-tenant runs only) -----------------------
        if mt:
            K = len(tenants)
            ten_l = src.tolist()
            tarr = [0] * K              # arrivals per tenant
            tdone = [0] * K             # completions per tenant
            tb_d = [0.0] * K            # DSA service-seconds per tenant
            tb_c = [0.0] * K            # CPU service-seconds per tenant
            # fleet-wide per-tenant live queue depth, time-weighted per
            # class (finalized to the common end-of-run horizon)
            tqa_d = [0.0] * K; tqa_c = [0.0] * K
            tqd_d = [0] * K; tqd_c = [0] * K
            tql_d = [0.0] * K; tql_c = [0.0] * K
            tqm_d = [0] * K; tqm_c = [0] * K

            def tacct_d(k: int, t: float, delta: int) -> None:
                tqa_d[k] += tqd_d[k] * (t - tql_d[k]); tql_d[k] = t
                v = tqd_d[k] + delta; tqd_d[k] = v
                if v > tqm_d[k]: tqm_d[k] = v

            def tacct_c(k: int, t: float, delta: int) -> None:
                tqa_c[k] += tqd_c[k] * (t - tql_c[k]); tql_c[k] = t
                v = tqd_c[k] + delta; tqd_c[k] = v
                if v > tqm_c[k]: tqm_c[k] = v
        else:
            ten_l = None

        # -- drive-scheduler state (non-FCFS modes) --------------------------
        if sk == 1:
            # weighted time-slicing: per-drive per-tenant FIFO queues, a
            # rotation cursor, the last tenant whose context is loaded on
            # the DSA, and per-request remaining service (-1 = not started)
            d_tq = [[deque() for _ in range(K)] for _ in range(nd)]
            d_cur = [-1] * nd
            d_rr = [0] * nd
            d_lastten = [-1] * nd
            rem_l = [-1.0] * n
            ts_q = [sched.quantum_s * t.weight for t in tenants]
            ts_switch = sched.switch_s
        elif sk == 2:
            # spatial partitioning: per (drive, tenant) lane-group FCFS
            # sub-servers; service inflated by total/assigned lanes
            lanes_total = sched.lanes or K
            lane_of = assign_lanes([t.weight for t in tenants], lanes_total)
            sp_scale = [lanes_total / l for l in lane_of]
            sp_q = [[deque() for _ in range(K)] for _ in range(nd)]
            sp_busy = [[0] * K for _ in range(nd)]

        # -- autoscaling state (inert without a controller) ------------------
        # The CPU pool scales by (de)activating a subset of the provisioned
        # nc nodes: inactive nodes take no new dispatch, drain what they
        # hold run-to-completion, then power off.  Drives power-cycle:
        # d_power is 1 (on) / 2 (waking) / 0 (off); an arrival for an off
        # drive starts a wake (the drive holds its queue, marked busy, and
        # a wake event fires dscs_wake_s later).  Powered-seconds per class
        # accumulate on power-off and finalize to the end-of-run horizon.
        dyn = controller is not None
        c_active = [True] * nc
        n_c_active = nc
        d_power = [1] * nd
        n_d_on = nd
        t_wake = ep_idx = 0
        if dyn:
            ep_s = float(controller.epoch_s)
            if ep_s <= 0.0:
                raise ValueError("controller.epoch_s must be positive")
            ep_t = ep_s
            wake_s = self.dscs_wake_s
            n_waking = 0                # drives held busy by a pending wake
            c_on_since = [0.0] * nc     # -1.0 once powered off
            d_on_since = [0.0] * nd
            # completed power-on intervals; kept as (start, stop) pairs so
            # finalization can clip them to the end-of-run horizon (stale
            # hedge timers / wake events let epochs fire past the last
            # completion, and power-offs there must not inflate powered_s)
            c_on_ivals: List[Tuple[float, float]] = []
            d_on_ivals: List[Tuple[float, float]] = []
            ep_last_ai = ep_last_done = 0
            ep_last_rej = ep_last_shed = 0
            if mt:
                ep_last_ta = [0] * K
                ep_last_tc = [0] * K
        else:
            ep_t = INF

        # -- fault-injection & deadline state (faults.py; inert without a
        # plan / timeout).  The expanded timeline is consumed through a
        # cursor like the arrival stream; retry timers reuse the
        # -(nd+1+rid) heap code range (mutually exclusive with time-slice
        # quanta: faults force the single-tenant FCFS path) and repair
        # completions use the constant code -(nd+1+n).
        if fa:
            horizon = (duration_s if duration_s > 0.0
                       else (float(times[-1]) if n else 0.0))
            ftl = fp.timeline(nd, nc, horizon, frng)
            fn = len(ftl)
            d_alive = [True] * nd
            c_alive = [True] * nc
            n_alive_active = nc         # alive AND active CPU nodes
            d_stall = [1.0] * nd        # live slowdown factor per drive
            d_run = [-1] * nd           # running request per drive
            c_run = [-1] * nc           # running request per CPU node
            att_l = [0] * n             # losses so far per request
            prevdel_l = [0.0] * n       # previous granted retry delay
            degr = {}                   # rid -> degraded-path fetch extra
            d_down_since = [-1.0] * nd
            d_down_s = [0.0] * nd
            rp = fp.retry
            rbud = fp.retry_budget
            det_s = fp.detect_timeout_s
            bf_p = fp.backing_fail_p
            bf_retry = fp.backing_retry_s
            lm_bf2 = self.lm.backing_fetch
            f_rb = [p.workload.request_bytes for p in pipelines]
            rb_granted = 0
            f_inj = [0] * 6             # timeline events applied, per kind
            f_cpu_skip = f_back_fail = 0
            f_lost = f_retry_sched = f_redisp = f_budget_deny = 0
            f_aband = f_degraded = f_detect = 0
            repair_on = (fp.repair is not None and tier_on and t_nobj > 0)
            if repair_on:
                rep_bw = fp.repair.bandwidth_bps
                rep_objbytes = (t_objbytes if t_objbytes
                                else sum(f_rb) / len(f_rb))
                rep_until = 0.0         # when the serialized pipe frees up
                rep_pending: deque = deque()
            rep_bytes = rep_s = 0.0
            rep_jobs = rep_objs = 0
        else:
            fn = 0
            ftl = ()
            det_s = None
        fi = 0
        dead_l = (bytearray(n) if (fa or timeout_s is not None or ov_on)
                  else None)
        t_dead = 0                      # deadline abandonments
        x_ev = 0                        # fault/retry/repair/deadline events
        dl_dq: deque = deque()          # (deadline, rid): FIFO, const offset
        det_dq: deque = deque()         # (detect time, rid): FIFO likewise

        # -- overload-control state (overload.py; inert without a config).
        # Every mechanism is a deterministic function of engine state —
        # token-bucket refill, queue-depth thresholds, head-age CoDel, the
        # pushback accumulator — so no random draw is taken and the
        # seed-derived streams never shift with the layer on or off.
        ov_admitted = ov_rej = ov_rej_push = ov_rej_adm = 0
        ov_shed = ov_cc = ov_retry_deny = ov_hedge_sup = 0
        ov_epochs = bro_entered = bro_ep_act = 0
        push_f = 1.0                    # current pushback factor
        bro_active = False              # brownout engaged
        ov_t = INF                      # next overload control epoch
        ov_gate_on = False              # arrival/retry admission gate live
        ov_maxq = None                  # bounded-queue shed threshold
        ov_incoming = False             # overflow victim: incoming copy
        ov_disp = False                 # dispatch-time sheds (hopeless/CoDel)
        if ov_on:
            adm = ov.admission
            if isinstance(adm, AdmitAll):
                adm = None              # the unconditional baseline
            shp = (ov.shed if (ov.shed is not None and ov.shed.enabled)
                   else None)
            bp = ov.backpressure
            bro = ov.brownout
            ov_ep_s = ov.epoch_s
            if bp is not None or bro is not None:
                ov_t = ov_ep_s          # epochs only drive those two
            ov_gate_on = adm is not None or bp is not None
            ov_adm_cls = [0, 0]; ov_rej_cls = [0, 0]; ov_shed_cls = [0, 0]
            ov_shed_by = [0, 0, 0]      # bounded / hopeless / codel
            push_acc = 0.0              # deterministic thinning accumulator
            push_tl: List[Tuple[float, float]] = []
            bro_above = 0               # consecutive epochs above on_depth
            bro_since = 0.0
            bro_ivals: List[Tuple[float, float]] = []
            K_ov = K if mt else 1
            if mt:
                ov_ten_adm = [0] * K; ov_ten_rej = [0] * K
                ov_ten_shed = [0] * K
            tb_on = isinstance(adm, TokenBucket)
            if tb_on:
                # buckets flattened [class][tenant], accel rows first; a
                # tenant's bucket is sized to its weight share so a greedy
                # tenant exhausts only its own allocation
                n_cls = 2 if adm.per_class else 1
                if mt:
                    wsum = sum(t2.weight for t2 in tenants)
                    shares = [t2.weight / wsum for t2 in tenants]
                else:
                    shares = [1.0]
                tb_rate = [adm.rate * s2 for s2 in shares] * n_cls
                tb_cap = [max(1.0, adm.burst * s2)
                          for s2 in shares] * n_cls
                tb_tok = list(tb_cap)   # buckets start full
                tb_last = [0.0] * (n_cls * K_ov)
            qt_on = isinstance(adm, QueueThreshold)
            if shp is not None:
                ov_maxq = shp.max_queue
                ov_incoming = shp.drop == "incoming"
                shp_hope = shp.hopeless and timeout_s is not None
                codel_t = shp.codel_target_s
                codel_i = shp.codel_interval_s
                ov_disp = shp_hope or codel_t is not None
                if codel_t is not None:
                    # per-server time the head age first exceeded target
                    codel_d = [-1.0] * nd
                    codel_c = [-1.0] * nc

            def ov_admit(rid2: int, t2: float) -> int:
                """The arrival/retry admission gate: 0 admit, 1 rejected
                by pushback (client-side throttling), 2 rejected by the
                admission policy."""
                nonlocal push_acc
                if push_f < 1.0:
                    # thin to exactly push_f of offered arrivals: the
                    # accumulator passes a request each time it crosses 1
                    push_acc += push_f
                    if push_acc >= 1.0:
                        push_acc -= 1.0
                    else:
                        return 1
                if tb_on:
                    idx = ((ten_l[rid2] if mt else 0)
                           + (0 if (n_cls == 1 or accel_l[rid2])
                              else K_ov))
                    tok = tb_tok[idx] + (t2 - tb_last[idx]) * tb_rate[idx]
                    cap = tb_cap[idx]
                    if tok > cap:
                        tok = cap
                    tb_last[idx] = t2
                    if tok >= 1.0:
                        tb_tok[idx] = tok - 1.0
                        return 0
                    tb_tok[idx] = tok
                    return 2
                if qt_on:
                    active = n_d_on + n_c_active
                    if active <= 0:
                        return 2
                    mq = adm.max_queue_per_server
                    if mq is not None and \
                            sum(d_qd) + sum(c_qd) > mq * active:
                        return 2
                    mu = adm.max_utilization
                    if mu is not None:
                        busy = sum(d_busy) + sum(c_busy)
                        if dyn:
                            busy -= n_waking
                        if busy > mu * active:
                            return 2
                return 0

            def ov_after_cancel(r2: int, t2: float, was_cpu: bool,
                                reason: int) -> None:
                """A queued copy was just shed (state already flipped to
                ``_CANCELLED`` and its queue accounting settled): when a
                sibling copy is still racing, only the copy dies; else the
                request itself is shed."""
                nonlocal ov_shed, ov_cc, end_t
                sib = ds_l[r2] if was_cpu else cs_l[r2]
                if sib == _QUEUED or sib == _RUNNING \
                        or winner_l[r2] >= 0 or dead_l[r2]:
                    ov_cc += 1
                    return
                dead_l[r2] = 1
                ov_shed += 1
                ov_shed_by[reason] += 1
                ov_shed_cls[0 if accel_l[r2] else 1] += 1
                if mt:
                    ov_ten_shed[ten_l[r2]] += 1
                if t2 > end_t:
                    end_t = t2

            def ov_drop_incoming(r2: int, t2: float) -> None:
                """Bounded-queue overflow with ``drop="incoming"``: the
                arriving/retried copy is never enqueued and the request is
                shed on the spot (callers rule out racing siblings)."""
                nonlocal ov_shed, end_t
                dead_l[r2] = 1
                ov_shed += 1
                ov_shed_by[0] += 1
                ov_shed_cls[0 if accel_l[r2] else 1] += 1
                if mt:
                    ov_ten_shed[ten_l[r2]] += 1
                if t2 > end_t:
                    end_t = t2

            def ov_evict_drive(d2: int, t2: float) -> None:
                """Shed the oldest live queued copy on drive ``d2`` to
                make room (``drop="oldest"`` overflow)."""
                nonlocal t_tomb
                dq2 = d_queues[d2]
                while dq2:
                    v = dq2.popleft()
                    if ds_l[v] == _CANCELLED:
                        t_tomb += 1
                        continue
                    d_area[d2] += d_qd[d2] * (t2 - d_last[d2])
                    d_last[d2] = t2
                    d_qd[d2] -= 1
                    ds_l[v] = _CANCELLED
                    if mt:
                        tacct_d(ten_l[v], t2, -1)
                    ov_after_cancel(v, t2, False, 0)
                    return

            def ov_evict_cpu(node2: int, t2: float) -> None:
                nonlocal t_tomb
                cq2 = c_queues[node2]
                while cq2:
                    v = cq2.popleft()
                    if cs_l[v] == _CANCELLED:
                        t_tomb += 1
                        continue
                    c_area[node2] += c_qd[node2] * (t2 - c_last[node2])
                    c_last[node2] = t2
                    c_qd[node2] -= 1
                    load2 = c_load[node2] - 1; c_load[node2] = load2
                    hpush(loadheap, (load2, node2))
                    cs_l[v] = _CANCELLED
                    if mt:
                        tacct_c(ten_l[v], t2, -1)
                    ov_after_cancel(v, t2, True, 0)
                    return

            def ov_shed_dispatch(r2: int, t2: float, cpu: bool,
                                 srv: int) -> int:
                """Dispatch-time shedding for the copy about to start
                service: deadline-hopeless first (even a zero-wait start
                cannot meet the request's deadline, judged against the
                deterministic service-time floor), then head-age CoDel
                (the dequeued copy's age stayed above target for a full
                interval; at most one shed per interval per server).
                Returns the shed_by reason index, or 0 to serve."""
                if shp_hope:
                    c2 = (coef_c if cpu else coef_d)[picks_l[r2]]
                    if t2 + c2[0] > times[r2] + timeout_s:
                        return 1
                if codel_t is not None:
                    first = codel_c if cpu else codel_d
                    age = t2 - times[r2]
                    if age > codel_t:
                        f0 = first[srv]
                        if f0 < 0.0:
                            first[srv] = t2
                        elif t2 - f0 >= codel_i:
                            first[srv] = t2
                            return 2
                    else:
                        first[srv] = -1.0
                return 0

        # -- dispatch helpers ------------------------------------------------
        if tier_on:
            lm_bf = self.lm.backing_fetch
            lm_chs = self.lm.cache_hit_savings
            _sav: Dict[int, float] = {}     # size -> cache-hit savings

            def tier_adjust(rid2: int, d2: int, svc: float) -> float:
                """Tier effects on one DSCS service start: a first access
                on a drive the object isn't materialized on pays the
                backing-store fill; a DRAM cache hit subtracts the
                flash-P2P + NS-driver savings."""
                nonlocal t_fill, fill_s
                o = obj_l[rid2] if obj_l is not None else rid2
                sz = t_objbytes or rb[picks_l[rid2]]
                m = mat[d2]
                if o not in m:
                    f = lm_bf(sz)
                    svc += f
                    fill_s += f; t_fill += 1
                    m.add(o)
                if caches is not None and caches[d2].access(o, sz):
                    sav = _sav.get(sz)
                    if sav is None:
                        sav = lm_chs(sz); _sav[sz] = sav
                    svc -= sav
                return svc if svc > 1e-9 else 1e-9

        def start_drive(d: int, t: float) -> None:
            nonlocal t_tomb, s_i, d_busy_s
            dq = d_queues[d]
            while dq:
                r2 = dq.popleft()
                st = ds_l[r2]
                if st == _CANCELLED:    # tombstone surfaced: discard, never start
                    t_tomb += 1
                    continue
                assert st == _QUEUED, "only queued copies may start service"
                if ov_disp:
                    why2 = ov_shed_dispatch(r2, t, False, d)
                    if why2:
                        d_area[d] += d_qd[d] * (t - d_last[d]); d_last[d] = t
                        d_qd[d] -= 1
                        ds_l[r2] = _CANCELLED
                        if mt:
                            tacct_d(ten_l[r2], t, -1)
                        ov_after_cancel(r2, t, False, why2)
                        continue
                d_area[d] += d_qd[d] * (t - d_last[d]); d_last[d] = t
                d_qd[d] -= 1
                ds_l[r2] = _RUNNING
                i = s_i
                if i == len(s_tr):
                    s_grow()
                s_i = i + 1
                c = coef_d[picks_l[r2]]
                svc = c[0] + c[1] * s_tr[i] + c[2] * s_tw[i]
                if tier_on:
                    svc = tier_adjust(r2, d, svc)
                if fa:
                    sf = d_stall[d]
                    if sf != 1.0:       # gray failure: slowed service
                        svc *= sf
                    d_run[d] = r2
                d_busy_s += svc
                d_start_a[r2] = t; d_svc_a[r2] = svc
                d_busy[d] = 1
                if mt:
                    k = ten_l[r2]
                    tacct_d(k, t, -1)
                    tb_d[k] += svc
                hpush(heap, (t + svc, r2 << 1))
                return

        def start_cpu(node: int, t: float) -> None:
            nonlocal t_tomb, s_i, c_busy_s
            cq = c_queues[node]
            while cq:
                r2 = cq.popleft()
                st = cs_l[r2]
                if st == _CANCELLED:
                    t_tomb += 1
                    continue
                assert st == _QUEUED, "only queued copies may start service"
                if ov_disp:
                    why2 = ov_shed_dispatch(r2, t, True, node)
                    if why2:
                        c_area[node] += c_qd[node] * (t - c_last[node])
                        c_last[node] = t
                        c_qd[node] -= 1
                        load2 = c_load[node] - 1; c_load[node] = load2
                        hpush(loadheap, (load2, node))
                        cs_l[r2] = _CANCELLED
                        if mt:
                            tacct_c(ten_l[r2], t, -1)
                        ov_after_cancel(r2, t, True, why2)
                        continue
                c_area[node] += c_qd[node] * (t - c_last[node])
                c_last[node] = t
                c_qd[node] -= 1
                cs_l[r2] = _RUNNING
                i = s_i
                if i == len(s_tr):
                    s_grow()
                s_i = i + 1
                c = coef_c[picks_l[r2]]
                svc = c[0] + c[1] * s_tr[i] + c[2] * s_tw[i]
                if fa:
                    ext = degr.get(r2)
                    if ext is not None: # degraded: remote backing fetch
                        svc += ext
                    c_run[node] = r2
                c_busy_s += svc
                c_start_a[r2] = t; c_svc_a[r2] = svc
                c_busy[node] = 1
                if mt:
                    k = ten_l[r2]
                    tacct_c(k, t, -1)
                    tb_c[k] += svc
                hpush(heap, (t + svc, (r2 << 1) | 1))
                return

        def issue_cpu(rid: int, t: float) -> None:
            nonlocal s_i, c_busy_s, ov_cc
            # least-loaded *active* CPU node, lowest index on ties: lazy
            # indexed heap (inactive nodes' entries are popped on sight; an
            # active node always holds its current entry — pushed on every
            # load change and on reactivation — so the heap never runs dry
            # while n_c_active >= 1, which the epoch handler guarantees)
            while True:
                load, node = loadheap[0]
                if c_load[node] == load and c_active[node] \
                        and (not fa or c_alive[node]):
                    break
                hpop(loadheap)          # stale, deactivated or dead entry
            if ov_maxq is not None and c_qd[node] >= ov_maxq \
                    and (c_busy[node] or c_queues[node]):
                # bounded CPU queue: shed the oldest live copy to make
                # room, or drop the incoming copy itself.  A dropped
                # hedge/detect copy leaves its DSCS sibling racing (copy-
                # level loss); a dropped primary copy sheds the request.
                if ov_incoming:
                    if ds_l[rid] == _QUEUED or ds_l[rid] == _RUNNING:
                        ov_cc += 1
                    else:
                        ov_drop_incoming(rid, t)
                    return
                ov_evict_cpu(node, t)
            c_node_l[rid] = node
            load += 1; c_load[node] = load
            hpush(loadheap, (load, node))
            if c_busy[node] or c_queues[node]:
                c_area[node] += c_qd[node] * (t - c_last[node])
                c_last[node] = t
                c_queues[node].append(rid)
                q = c_qd[node] + 1; c_qd[node] = q
                if q > c_maxd[node]: c_maxd[node] = q
                cs_l[rid] = _QUEUED
                if mt:
                    tacct_c(ten_l[rid], t, 1)
                # a server only goes idle by draining its deque to empty
                # (discarding tombstones), so nonempty deque => busy
                assert c_busy[node], "idle CPU node held a nonempty queue"
            else:
                # idle node: start immediately (transient depth 1)
                c_last[node] = t
                if not c_maxd[node]: c_maxd[node] = 1
                cs_l[rid] = _RUNNING
                i = s_i
                if i == len(s_tr):
                    s_grow()
                s_i = i + 1
                c = coef_c[picks_l[rid]]
                svc = c[0] + c[1] * s_tr[i] + c[2] * s_tw[i]
                if fa:
                    ext = degr.get(rid)
                    if ext is not None:
                        svc += ext
                    c_run[node] = rid
                c_busy_s += svc
                c_start_a[rid] = t; c_svc_a[rid] = svc
                c_busy[node] = 1
                if mt:
                    tb_c[ten_l[rid]] += svc
                hpush(heap, (t + svc, (rid << 1) | 1))

        if fa:
            def degrade(rid2: int, t: float) -> None:
                """Every replica of the request's object is down (or its
                home drive is dead, tier off): serve on the CPU path with
                the object fetched from the remote backing store, each
                fetch attempt failing independently with ``backing_fail_p``
                (failed attempts cost ``backing_retry_s`` apiece)."""
                nonlocal f_degraded, f_back_fail
                f_degraded += 1
                sz = (t_objbytes or rb[picks_l[rid2]]) if tier_on \
                    else f_rb[picks_l[rid2]]
                ext = lm_bf2(sz)
                if bf_p > 0.0:
                    while frng.random() < bf_p:
                        f_back_fail += 1
                        ext += bf_retry
                degr[rid2] = ext
                issue_cpu(rid2, t)

            def try_retry(rid2: int, t: float) -> None:
                """One copy of ``rid2`` was just lost and no other copy is
                live: grant a retry (backoff delay on the heap) under the
                policy + budget, or abandon the request."""
                nonlocal f_retry_sched, f_aband, f_budget_deny, \
                    rb_granted, end_t, ov_retry_deny
                if ov_gate_on and ov_admit(rid2, t):
                    # retries consult the same admission gate as fresh
                    # arrivals, so backoff cannot storm a pushed-back or
                    # token-exhausted fleet: the denied retry abandons
                    ov_retry_deny += 1
                    dead_l[rid2] = 1
                    f_aband += 1
                    if t > end_t:
                        end_t = t
                    return
                att = att_l[rid2] + 1
                att_l[rid2] = att
                delay = None
                if rbud is None or rbud.allows(rb_granted, ai):
                    delay = rp.delay_s(att, prevdel_l[rid2], frng)
                else:
                    f_budget_deny += 1
                if delay is None:
                    dead_l[rid2] = 1
                    f_aband += 1
                    if t > end_t:
                        end_t = t
                    return
                prevdel_l[rid2] = delay
                rb_granted += 1
                f_retry_sched += 1
                hpush(heap, (t + delay, -(nd + 1 + rid2)))

            def redispatch(rid2: int, t: float) -> None:
                """A granted retry timer fired: re-dispatch the request to
                a surviving drive (alive replicas under tiering, the home
                drive otherwise), to a surviving CPU node for
                non-acceleratable requests, or degrade when no drive
                holding the object survives."""
                nonlocal f_redisp, n_d_on, n_waking, t_wake
                if not accel_l[rid2]:
                    f_redisp += 1
                    issue_cpu(rid2, t)
                    return
                d = -1
                if tier_on:
                    o = obj_l[rid2] if obj_l is not None else rid2
                    reps = replicas[o]
                    best = None
                    for d2 in reps:
                        if not d_alive[d2]:
                            continue
                        key2 = (1 if (dyn and not d_power[d2]) else 0,
                                d_qd[d2] + d_busy[d2],
                                0 if (caches is not None
                                      and caches[d2].warm(o)) else 1,
                                d2)
                        if best is None or key2 < best:
                            best = key2; d = d2
                else:
                    d0 = drive_l[rid2]
                    if d_alive[d0]:
                        d = d0
                if d < 0:
                    degrade(rid2, t)
                    return
                if ov_maxq is not None and d_qd[d] >= ov_maxq:
                    if ov_incoming:
                        ov_drop_incoming(rid2, t)
                        return
                    ov_evict_drive(d, t)
                f_redisp += 1
                drive_l[rid2] = d
                ds_l[rid2] = _QUEUED
                if dyn and d_power[d] == 0:
                    d_power[d] = 2
                    n_d_on += 1
                    n_waking += 1
                    d_on_since[d] = t
                    d_busy[d] = 1
                    hpush(heap, (t + wake_s, -(d + 1)))
                    t_wake += 1
                d_area[d] += d_qd[d] * (t - d_last[d]); d_last[d] = t
                d_queues[d].append(rid2)
                q = d_qd[d] + 1; d_qd[d] = q
                if q > d_maxd[d]: d_maxd[d] = q
                if not d_busy[d]:
                    start_drive(d, t)

            def schedule_repair(dd: int, t: float) -> None:
                """Drive ``dd`` just left the fleet (fail-stop or
                autoscaler power-down): queue the re-replication of every
                object that kept a replica there onto surviving drives
                (HRW order), through the serialized repair pipe.  The
                replica table is patched when the transfer completes."""
                nonlocal rep_until
                if not repair_on:
                    return
                moves = []
                for o2, r2 in enumerate(replicas):
                    if dd in r2:
                        for cand in _hrw_ranking(f"obj-{o2}", nd):
                            if cand != dd and d_alive[cand] \
                                    and cand not in r2:
                                moves.append((o2, dd, cand))
                                break
                if not moves:
                    return
                nbytes = len(moves) * rep_objbytes
                start = rep_until if rep_until > t else t
                rep_until = start + nbytes / rep_bw
                rep_pending.append((nbytes, moves))
                hpush(heap, (rep_until, -(nd + 1 + n)))

        if sk == 1:
            def ts_select(d: int, t: float) -> None:
                """Weighted-round-robin scheduling decision for drive ``d``:
                serve the next backlogged tenant's head copy for at most
                its weighted quantum, paying the context-switch cost when
                the serving tenant changes.  Tombstoned (cancelled while
                queued) copies are discarded on sight."""
                nonlocal t_tomb, s_i, d_busy_s, t_switch_s
                tq = d_tq[d]
                sel = -1
                cursor = d_rr[d]
                for step in range(1, K + 1):
                    k = (cursor + step) % K
                    q = tq[k]
                    while q and ds_l[q[0]] == _CANCELLED:
                        q.popleft()     # tombstone (reclaim counted at cancel)
                        t_tomb += 1
                    if q:
                        sel = k
                        break
                if sel < 0:
                    d_cur[d] = -1
                    d_busy[d] = 0
                    return
                rid2 = tq[sel].popleft()
                d_area[d] += d_qd[d] * (t - d_last[d]); d_last[d] = t
                d_qd[d] -= 1
                tacct_d(sel, t, -1)
                pay = 0.0
                if d_lastten[d] != sel:
                    if d_lastten[d] >= 0:
                        pay = ts_switch
                        t_switch_s += pay
                    d_lastten[d] = sel
                d_rr[d] = sel
                if rem_l[rid2] < 0.0:   # first start: draw the full service
                    i = s_i
                    if i == len(s_tr):
                        s_grow()
                    s_i = i + 1
                    c = coef_d[picks_l[rid2]]
                    svc = c[0] + c[1] * s_tr[i] + c[2] * s_tw[i]
                    rem_l[rid2] = svc
                    d_start_a[rid2] = t + pay
                    d_svc_a[rid2] = svc
                ds_l[rid2] = _RUNNING
                d_cur[d] = rid2
                d_busy[d] = 1
                rem = rem_l[rid2]
                q_s = ts_q[sel]
                seg = rem if rem <= q_s else q_s
                d_busy_s += pay + seg
                tb_d[sel] += pay + seg
                if rem <= q_s:          # final segment: completion event
                    hpush(heap, (t + pay + rem, rid2 << 1))
                else:                   # quantum expiry: preempt event
                    hpush(heap, (t + pay + q_s, -(nd + 1 + rid2)))
        elif sk == 2:
            def sp_start_new(d: int, k: int, rid2: int, t: float) -> None:
                """Idle lane group: start ``rid2`` immediately (transient
                depth 1), service inflated by the tenant's lane share."""
                nonlocal s_i, d_busy_s
                # settle the drive's pending depth area first: unlike an
                # idle FCFS drive, an idle *lane* can coexist with copies
                # queued on the drive's other lanes (d_qd > 0)
                d_area[d] += d_qd[d] * (t - d_last[d])
                d_last[d] = t
                if not d_maxd[d]: d_maxd[d] = 1
                ds_l[rid2] = _RUNNING
                i = s_i
                if i == len(s_tr):
                    s_grow()
                s_i = i + 1
                c = coef_d[picks_l[rid2]]
                svc = (c[0] + c[1] * s_tr[i] + c[2] * s_tw[i]) * sp_scale[k]
                d_busy_s += svc
                tb_d[k] += svc
                d_start_a[rid2] = t; d_svc_a[rid2] = svc
                sp_busy[d][k] = 1
                hpush(heap, (t + svc, rid2 << 1))

            def sp_start(d: int, k: int, t: float) -> None:
                """Start the next queued copy on drive ``d``'s lane group
                for tenant ``k``, discarding tombstones."""
                nonlocal t_tomb, s_i, d_busy_s
                q = sp_q[d][k]
                while q:
                    rid2 = q.popleft()
                    if ds_l[rid2] == _CANCELLED:
                        t_tomb += 1
                        continue
                    assert ds_l[rid2] == _QUEUED, \
                        "only queued copies may start service"
                    d_area[d] += d_qd[d] * (t - d_last[d]); d_last[d] = t
                    d_qd[d] -= 1
                    tacct_d(k, t, -1)
                    ds_l[rid2] = _RUNNING
                    i = s_i
                    if i == len(s_tr):
                        s_grow()
                    s_i = i + 1
                    c = coef_d[picks_l[rid2]]
                    svc = (c[0] + c[1] * s_tr[i] + c[2] * s_tw[i]) \
                        * sp_scale[k]
                    d_busy_s += svc
                    tb_d[k] += svc
                    d_start_a[rid2] = t; d_svc_a[rid2] = svc
                    sp_busy[d][k] = 1
                    hpush(heap, (t + svc, rid2 << 1))
                    return

        # -- main loop -------------------------------------------------------
        # Event order: arrivals win every tie (they had the lowest sequence
        # numbers in the PR-1 heap); hedge timers share one constant budget
        # so they fire in FIFO order from hedge_dq; finish events order by
        # (time, copy id) — service times are continuous draws, so exact
        # finish-time ties have measure zero and the golden-trace gates pin
        # that the ordering stays equivalent.
        ai = 0
        base = 0
        if n:
            limit = min(n, _CHUNK)
            times_l = times[:limit].tolist()
            next_t = times_l[0]
        else:
            limit, times_l, next_t = 0, [], INF

        while True:
            ft = heap[0][0] if heap else INF
            ht = hedge_dq[0][0] if hedge_dq else INF
            fault_t = ftl[fi][0] if fi < fn else INF
            dlt = dl_dq[0][0] if dl_dq else INF
            dtt = det_dq[0][0] if det_dq else INF
            if ov_t <= ft and ov_t <= ht and ov_t < ep_t and \
                    ov_t <= mig_t and ov_t <= fault_t and ov_t <= dlt and \
                    ov_t <= dtt and ov_t < next_t and \
                    (next_t != INF or heap or hedge_dq):
                # overload control epoch: derive the pushback factor and
                # the brownout state from the live queue depth per active
                # server.  Same-time autoscale epochs win the tie (strict
                # ov_t < ep_t), arrivals win against both, and the epoch
                # stream stops once the fleet has drained.
                t = ov_t
                ov_epochs += 1
                active = n_d_on + n_c_active
                depth = ((sum(d_qd) + sum(c_qd)) / active
                         if active else 0.0)
                if bp is not None:
                    f2 = 1.0
                    if depth > bp.target_depth:
                        f2 = bp.target_depth / depth
                        if f2 < bp.min_factor:
                            f2 = bp.min_factor
                    if f2 != push_f:
                        push_f = f2
                        push_tl.append((t, f2))
                if bro is not None:
                    if bro_active:
                        if depth <= bro.off_depth:
                            bro_active = False
                            bro_ivals.append((bro_since, t))
                            bro_above = 0
                        else:
                            bro_ep_act += 1
                    elif depth >= bro.on_depth:
                        bro_above += 1
                        if bro_above >= bro.min_epochs:
                            bro_active = True
                            bro_entered += 1
                            bro_since = t
                            bro_ep_act += 1
                    else:
                        bro_above = 0
                ov_t += ov_ep_s
                continue
            if ep_t <= ft and ep_t <= ht and ep_t <= mig_t and \
                    ep_t <= fault_t and ep_t <= dlt and ep_t <= dtt and \
                    ep_t < next_t and (next_t != INF or heap or hedge_dq):
                # epoch boundary: snapshot telemetry, apply the controller's
                # action.  Fires before same-time dynamic events, after
                # same-time arrivals, and stops once the fleet has drained.
                t = ep_t
                ep_idx += 1
                done = t_srv_d + t_srv_c + t_won_d + t_won_c
                if mt:
                    snap_tq = tuple(tqd_d[k] + tqd_c[k] for k in range(K))
                    snap_ta = tuple(a - b for a, b in zip(tarr, ep_last_ta))
                    snap_tc = tuple(a - b for a, b in zip(tdone, ep_last_tc))
                    ep_last_ta = list(tarr)
                    ep_last_tc = list(tdone)
                else:
                    snap_tq = snap_ta = snap_tc = ()
                act = controller.observe(FleetSnapshot(
                    time=t, epoch=ep_idx,
                    arrivals=ai - ep_last_ai,
                    completions=done - ep_last_done,
                    dscs_queue=sum(d_qd), cpu_queue=sum(c_qd),
                    dscs_busy=sum(d_busy) - n_waking, cpu_busy=sum(c_busy),
                    n_cpu_active=n_c_active, n_dscs_on=n_d_on,
                    n_cpu_total=nc, n_dscs_total=nd,
                    tenant_queue=snap_tq, tenant_arrivals=snap_ta,
                    tenant_completions=snap_tc,
                    rejected=ov_rej - ep_last_rej,
                    shed=ov_shed - ep_last_shed, pushback=push_f))
                ep_last_ai, ep_last_done = ai, done
                ep_last_rej, ep_last_shed = ov_rej, ov_shed
                if act is not None:
                    # CPU pool: activate lowest-index first / deactivate
                    # highest-index first (deterministic); a deactivated
                    # node drains run-to-completion, then powers off
                    want_c = min(nc, max(1, int(act.n_cpu)))
                    if want_c > n_c_active:
                        for node in range(nc):
                            if n_c_active >= want_c:
                                break
                            if not c_active[node]:
                                c_active[node] = True
                                n_c_active += 1
                                if fa and c_alive[node]:
                                    n_alive_active += 1
                                if c_on_since[node] < 0.0 and \
                                        (not fa or c_alive[node]):
                                    c_on_since[node] = t
                                hpush(loadheap, (c_load[node], node))
                    elif want_c < n_c_active:
                        for node in range(nc - 1, -1, -1):
                            if n_c_active <= want_c:
                                break
                            if c_active[node]:
                                if fa and c_alive[node] \
                                        and n_alive_active <= 1:
                                    continue    # keep one live CPU node
                                c_active[node] = False
                                n_c_active -= 1
                                if fa and c_alive[node]:
                                    n_alive_active -= 1
                                if not c_busy[node] and not c_queues[node] \
                                        and c_on_since[node] >= 0.0:
                                    c_on_ivals.append((c_on_since[node], t))
                                    c_on_since[node] = -1.0
                    # drives: power on lowest-index off drives (they wake,
                    # serving after dscs_wake_s) / power off highest-index
                    # idle drives (busy, waking or backlogged drives are
                    # never yanked — best effort toward the target)
                    want_d = min(nd, max(0, int(act.n_dscs_on)))
                    if want_d > n_d_on:
                        for d in range(nd):
                            if n_d_on >= want_d:
                                break
                            if fa and not d_alive[d]:
                                continue    # dead drives cannot be woken
                            if d_power[d] == 0:
                                d_power[d] = 2
                                n_d_on += 1
                                n_waking += 1
                                d_on_since[d] = t
                                d_busy[d] = 1
                                hpush(heap, (t + wake_s, -(d + 1)))
                                t_wake += 1
                    elif want_d < n_d_on:
                        for d in range(nd - 1, -1, -1):
                            if n_d_on <= want_d:
                                break
                            if (d_power[d] == 1 and not d_busy[d]
                                    and not d_queues[d]):
                                d_power[d] = 0
                                n_d_on -= 1
                                d_on_ivals.append((d_on_since[d], t))
                                d_on_since[d] = -1.0
                                if fa:
                                    # an autoscaler power-down removes the
                                    # drive's replicas from service just
                                    # like a fail-stop: re-replicate them
                                    # (ROADMAP "replication under the
                                    # autoscaler" follow-on)
                                    schedule_repair(d, t)
                ep_t += ep_s
                continue
            if mig_t <= ft and mig_t <= ht and mig_t < ep_t and \
                    mig_t <= fault_t and mig_t <= dlt and mig_t <= dtt and \
                    mig_t < next_t and (next_t != INF or heap or hedge_dq):
                # hot-key migration epoch: rebalance the replica table from
                # the live per-drive backlogs and this epoch's access
                # counts.  A moved key only retargets *routing* — the
                # durable copy materializes on its new drive through a
                # backing-store fetch on first access, like a lazy replica.
                for o2, frm, to in mig.plan(mig_t, d_qd, d_busy, acc,
                                            replicas):
                    r2 = replicas[o2]
                    r2[r2.index(frm)] = to
                for a2 in acc:
                    a2.clear()
                mig_t += mig_s
                continue
            if fault_t <= ft and fault_t <= ht and fault_t < ep_t and \
                    fault_t < mig_t and fault_t <= dlt and fault_t <= dtt \
                    and fault_t < next_t:
                # injected fault from the plan's timeline (self-
                # terminating: the cursor only ever advances)
                t, kind, srv, extra = ftl[fi]
                fi += 1
                x_ev += 1
                if kind == DRIVE_FAIL:
                    d = srv
                    if not d_alive[d]:
                        continue        # overlapping window: already dead
                    d_alive[d] = False
                    f_inj[DRIVE_FAIL] += 1
                    d_down_since[d] = t
                    lost = []
                    dq = d_queues[d]
                    if dq or d_qd[d]:
                        d_area[d] += d_qd[d] * (t - d_last[d])
                        d_last[d] = t
                        while dq:
                            r2 = dq.popleft()
                            if ds_l[r2] == _CANCELLED:
                                t_tomb += 1
                                continue
                            ds_l[r2] = _CANCELLED
                            lost.append(r2)
                        d_qd[d] = 0
                    r3 = d_run[d]
                    if r3 >= 0:
                        left = d_start_a[r3] + d_svc_a[r3] - t
                        d_busy_s -= left
                        if ds_l[r3] != _CANCELLED:  # not a draining loser
                            lost.append(r3)
                        else:
                            rec_d += left
                        ds_l[r3] = _PREEMPTED
                        # invalidate the recorded service so the dead
                        # copy's in-heap finish event can never match a
                        # later re-dispatch that is still queued (NaN
                        # fails the exact-time staleness check)
                        d_svc_a[r3] = NAN
                        d_run[d] = -1
                    d_busy[d] = 0
                    if dyn:
                        if d_power[d] == 2:
                            n_waking -= 1   # stale wake event skipped later
                        if d_power[d] != 0:
                            n_d_on -= 1
                            d_on_ivals.append((d_on_since[d], t))
                            d_on_since[d] = -1.0
                    d_power[d] = 0
                    schedule_repair(d, t)
                    for r2 in lost:
                        if winner_l[r2] >= 0 or dead_l[r2]:
                            continue
                        f_lost += 1
                        cst = cs_l[r2]
                        if cst == _QUEUED or cst == _RUNNING:
                            continue    # the hedge copy races on
                        try_retry(r2, t)
                elif kind == DRIVE_RECOVER:
                    d = srv
                    if d_alive[d]:
                        continue
                    d_alive[d] = True
                    f_inj[DRIVE_RECOVER] += 1
                    d_down_s[d] += t - d_down_since[d]
                    d_down_since[d] = -1.0
                    if tier_on:
                        # the replacement drive comes back empty: durable
                        # copies refill lazily from the backing store
                        mat[d].clear()
                    d_power[d] = 1
                    d_busy[d] = 0
                    if dyn:
                        n_d_on += 1
                        d_on_since[d] = t
                elif kind == STALL_BEGIN:
                    if d_alive[srv]:
                        f_inj[STALL_BEGIN] += 1
                    d_stall[srv] = extra
                elif kind == STALL_END:
                    d_stall[srv] = 1.0
                elif kind == CPU_CRASH:
                    node = srv
                    if not c_alive[node]:
                        continue
                    if c_active[node] and n_alive_active <= 1:
                        f_cpu_skip += 1  # never kill the last live node
                        continue
                    c_alive[node] = False
                    f_inj[CPU_CRASH] += 1
                    if c_active[node]:
                        n_alive_active -= 1
                    lost = []
                    cq = c_queues[node]
                    if cq or c_qd[node]:
                        c_area[node] += c_qd[node] * (t - c_last[node])
                        c_last[node] = t
                        while cq:
                            r2 = cq.popleft()
                            if cs_l[r2] == _CANCELLED:
                                t_tomb += 1
                                continue
                            cs_l[r2] = _CANCELLED
                            lost.append(r2)
                        c_qd[node] = 0
                    r3 = c_run[node]
                    if r3 >= 0:
                        left = c_start_a[r3] + c_svc_a[r3] - t
                        c_busy_s -= left
                        if cs_l[r3] != _CANCELLED:
                            lost.append(r3)
                        else:
                            rec_c += left
                        cs_l[r3] = _PREEMPTED
                        c_svc_a[r3] = NAN   # kill the stale finish event
                        c_run[node] = -1
                    c_busy[node] = 0
                    c_load[node] = 0
                    if dyn and c_on_since[node] >= 0.0:
                        c_on_ivals.append((c_on_since[node], t))
                        c_on_since[node] = -1.0
                    for r2 in lost:
                        if winner_l[r2] >= 0 or dead_l[r2]:
                            continue
                        f_lost += 1
                        dst = ds_l[r2]
                        if dst == _QUEUED or dst == _RUNNING:
                            continue    # the DSCS copy races on
                        try_retry(r2, t)
                else:                   # CPU_RECOVER
                    node = srv
                    if c_alive[node]:
                        continue
                    c_alive[node] = True
                    f_inj[CPU_RECOVER] += 1
                    if c_active[node]:
                        n_alive_active += 1
                        hpush(loadheap, (c_load[node], node))
                        if dyn and c_on_since[node] < 0.0:
                            c_on_since[node] = t
                continue
            if dlt <= ft and dlt <= ht and dlt < ep_t and dlt < mig_t \
                    and dlt <= dtt and dlt < next_t:
                # per-request deadline: cancel whatever is still pending
                # (queued copies tombstone; running copies free their
                # server and return the unserved remainder)
                t, rid = dl_dq.popleft()
                x_ev += 1
                if winner_l[rid] >= 0 or dead_l[rid]:
                    continue
                dst = ds_l[rid]
                if dst == _QUEUED:
                    d = drive_l[rid]
                    d_area[d] += d_qd[d] * (t - d_last[d]); d_last[d] = t
                    d_qd[d] -= 1
                    ds_l[rid] = _CANCELLED
                elif dst == _RUNNING:
                    ds_l[rid] = _PREEMPTED
                    d = drive_l[rid]
                    left = d_start_a[rid] + d_svc_a[rid] - t
                    rec_d += left
                    d_busy_s -= left
                    d_busy[d] = 0
                    if fa:
                        d_run[d] = -1
                    if d_queues[d]:
                        start_drive(d, t)
                cst = cs_l[rid]
                if cst == _QUEUED:
                    node = c_node_l[rid]
                    c_area[node] += c_qd[node] * (t - c_last[node])
                    c_last[node] = t
                    c_qd[node] -= 1
                    load = c_load[node] - 1; c_load[node] = load
                    hpush(loadheap, (load, node))
                    cs_l[rid] = _CANCELLED
                elif cst == _RUNNING:
                    cs_l[rid] = _PREEMPTED
                    node = c_node_l[rid]
                    left = c_start_a[rid] + c_svc_a[rid] - t
                    rec_c += left
                    c_busy_s -= left
                    c_busy[node] = 0
                    if fa:
                        c_run[node] = -1
                    load = c_load[node] - 1; c_load[node] = load
                    hpush(loadheap, (load, node))
                    if c_queues[node]:
                        start_cpu(node, t)
                    if dyn and not c_active[node] and not c_busy[node] \
                            and not c_queues[node] \
                            and c_on_since[node] >= 0.0:
                        c_on_ivals.append((c_on_since[node], t))
                        c_on_since[node] = -1.0
                dead_l[rid] = 1
                t_dead += 1
                if t > end_t:
                    end_t = t
                continue
            if dtt <= ft and dtt <= ht and dtt < ep_t and dtt < mig_t \
                    and dtt < next_t:
                # timeout-based failure detection: the DSCS copy is still
                # unfinished detect_timeout_s after dispatch (stalled or
                # backlogged drive) — hedge it on the CPU path now
                t, rid = det_dq.popleft()
                x_ev += 1
                if winner_l[rid] < 0 and not dead_l[rid] \
                        and cs_l[rid] == _FREE \
                        and (ds_l[rid] == _QUEUED
                             or ds_l[rid] == _RUNNING):
                    hedged_l[rid] = True
                    f_detect += 1
                    issue_cpu(rid, t)
                continue
            if ht <= ft:
                if ht < next_t:         # hedge timer fires
                    t, rid = hedge_dq.popleft()
                    # still waiting (and, under time-slicing, never
                    # serviced — a preempted copy re-queues as _QUEUED but
                    # holds partial progress, so it is no straggler)
                    if ds_l[rid] == _QUEUED and (sk != 1
                                                 or rem_l[rid] < 0.0) \
                            and (not fa or cs_l[rid] == _FREE):
                        # under faults a detection hedge may already have
                        # issued the CPU copy; never issue a third
                        if bro_active:
                            # brownout: hedging suspended under sustained
                            # overload — the request degrades to the
                            # single-copy path.  (Failure-*detection*
                            # hedges stay active: they rescue stuck
                            # requests rather than shave tails.)
                            ov_hedge_sup += 1
                        else:
                            hedged_l[rid] = True
                            t_hedge += 1
                            issue_cpu(rid, t)
                    continue
            elif ft < next_t:           # a dynamic event fires
                t, code = hpop(heap)
                if code < 0:
                    k2 = -code - 1
                    if k2 < nd:         # wake event: drive is serviceable
                        d = k2
                        if fa and d_power[d] != 2:
                            continue    # drive failed while waking
                        assert d_power[d] == 2, \
                            "wake event for a non-waking drive"
                        d_power[d] = 1
                        d_busy[d] = 0
                        n_waking -= 1
                        if d_queues[d]:
                            start_drive(d, t)
                        continue
                    if fa:
                        # the -(nd+1+...) code range holds retry timers
                        # (rid < n) and repair completions (rid == n) on
                        # faulted runs — time-slicing is mutually
                        # exclusive with fault injection
                        rid = k2 - nd
                        x_ev += 1
                        if rid >= n:    # repair transfer completed
                            nbytes, moves = rep_pending.popleft()
                            for o2, frm, tgt in moves:
                                r2 = replicas[o2]
                                if frm in r2 and d_alive[tgt]:
                                    r2[r2.index(frm)] = tgt
                                    mat[tgt].add(o2)
                                    rep_objs += 1
                            rep_bytes += nbytes
                            rep_s += nbytes / rep_bw
                            rep_jobs += 1
                            continue
                        if winner_l[rid] >= 0 or dead_l[rid] \
                                or ds_l[rid] == _QUEUED \
                                or ds_l[rid] == _RUNNING \
                                or cs_l[rid] == _QUEUED \
                                or cs_l[rid] == _RUNNING:
                            continue    # resolved, or a copy is racing
                        redispatch(rid, t)
                        continue
                    # time-slice quantum expiry: preempt the running copy
                    rid = k2 - nd
                    t_pre += 1
                    d = drive_l[rid]
                    k = ten_l[rid]
                    rem_l[rid] -= ts_q[k]
                    if ds_l[rid] == _CANCELLED:
                        # hedge loser caught mid-slice: drop it at the
                        # quantum boundary and reclaim the remainder
                        # (time-slicing always preempts — the §V run-to-
                        # completion argument doesn't apply to a DSA that
                        # already context-switches)
                        ds_l[rid] = _PREEMPTED
                        rec_d += rem_l[rid]
                    else:
                        # resume at the tenant's next turn (head of queue)
                        d_tq[d][k].appendleft(rid)
                        ds_l[rid] = _QUEUED
                        d_area[d] += d_qd[d] * (t - d_last[d])
                        d_last[d] = t
                        q = d_qd[d] + 1; d_qd[d] = q
                        if q > d_maxd[d]: d_maxd[d] = q
                        tacct_d(k, t, 1)
                    d_cur[d] = -1
                    ts_select(d, t)
                    continue
                rid = code >> 1
                if code & 1:            # CPU copy finished
                    if cs_l[rid] == _PREEMPTED:
                        continue        # stale: node freed at cancellation
                    if fa and t != c_start_a[rid] + c_svc_a[rid]:
                        # stale event of a copy lost to a fault and since
                        # re-issued: the live copy's own event carries the
                        # recomputed (bit-identical) start + service time
                        continue
                    end_t = t
                    node = c_node_l[rid]
                    c_busy[node] = 0
                    if fa:
                        c_run[node] = -1
                    load = c_load[node] - 1; c_load[node] = load
                    hpush(loadheap, (load, node))
                    if cs_l[rid] == _CANCELLED:
                        cfin_a[rid] = t        # run-to-completion loser drains
                    else:
                        cs_l[rid] = _DONE
                        finish_a[rid] = t
                        winner_l[rid] = 1
                        cfin_a[rid] = t
                        if mt:
                            tdone[ten_l[rid]] += 1
                        dst = ds_l[rid]
                        if dst == _QUEUED:     # tombstone the DSCS loser
                            d = drive_l[rid]
                            d_area[d] += d_qd[d] * (t - d_last[d])
                            d_last[d] = t
                            d_qd[d] -= 1
                            ds_l[rid] = _CANCELLED
                            t_can_q += 1
                            if mt:
                                tacct_d(ten_l[rid], t, -1)
                            if sk == 1 and rem_l[rid] >= 0.0:
                                # preempted copy cancelled while waiting
                                # its next slice: its remainder is
                                # reclaimed DSA time
                                rec_d += rem_l[rid]
                        elif dst == _RUNNING:
                            ds_l[rid] = _CANCELLED
                            t_can_s += 1
                            if preempt and sk != 1:
                                # preemptive cancellation: free the DSA
                                # now and reclaim the loser's remaining
                                # service (its stale finish event is
                                # skipped on pop); time-slicing instead
                                # drops the copy at its quantum boundary
                                ds_l[rid] = _PREEMPTED
                                d = drive_l[rid]
                                left = d_start_a[rid] + d_svc_a[rid] - t
                                rec_d += left
                                d_busy_s -= left
                                if mt:
                                    tb_d[ten_l[rid]] -= left
                                if sk == 0:
                                    d_busy[d] = 0
                                    if fa:
                                        d_run[d] = -1
                                    if d_queues[d]:
                                        start_drive(d, t)
                                else:
                                    k = ten_l[rid]
                                    sp_busy[d][k] = 0
                                    if sp_q[d][k]:
                                        sp_start(d, k, t)
                        if hedged_l[rid]:
                            t_won_c += 1
                        else:
                            t_srv_c += 1
                    if c_queues[node]:
                        start_cpu(node, t)
                    if dyn and not c_active[node] and not c_busy[node] \
                            and not c_queues[node] and c_on_since[node] >= 0.0:
                        # deactivated node fully drained: power it off
                        c_on_ivals.append((c_on_since[node], t))
                        c_on_since[node] = -1.0
                else:                   # DSCS copy finished
                    if ds_l[rid] == _PREEMPTED:
                        continue        # stale: drive freed at cancellation
                    if fa and t != d_start_a[rid] + d_svc_a[rid]:
                        continue        # stale event of a re-dispatched copy
                    end_t = t
                    d = drive_l[rid]
                    if ds_l[rid] == _CANCELLED:
                        dfin_a[rid] = t
                    else:
                        ds_l[rid] = _DONE
                        finish_a[rid] = t
                        winner_l[rid] = 0
                        dfin_a[rid] = t
                        if mt:
                            tdone[ten_l[rid]] += 1
                        if hedged_l[rid]:
                            t_won_d += 1
                            cst = cs_l[rid]
                            if cst == _QUEUED:     # tombstone the CPU loser
                                node = c_node_l[rid]
                                c_area[node] += c_qd[node] * (t - c_last[node])
                                c_last[node] = t
                                c_qd[node] -= 1
                                load = c_load[node] - 1; c_load[node] = load
                                hpush(loadheap, (load, node))
                                cs_l[rid] = _CANCELLED
                                t_can_q += 1
                                if mt:
                                    tacct_c(ten_l[rid], t, -1)
                            elif cst == _RUNNING:
                                cs_l[rid] = _CANCELLED
                                t_can_s += 1
                                if preempt:
                                    # preemptive cancellation of the CPU
                                    # loser: free the node immediately
                                    cs_l[rid] = _PREEMPTED
                                    node = c_node_l[rid]
                                    left = (c_start_a[rid] + c_svc_a[rid]
                                            - t)
                                    rec_c += left
                                    c_busy_s -= left
                                    if mt:
                                        tb_c[ten_l[rid]] -= left
                                    c_busy[node] = 0
                                    if fa:
                                        c_run[node] = -1
                                    load = c_load[node] - 1
                                    c_load[node] = load
                                    hpush(loadheap, (load, node))
                                    if c_queues[node]:
                                        start_cpu(node, t)
                                    if dyn and not c_active[node] \
                                            and not c_busy[node] \
                                            and not c_queues[node] \
                                            and c_on_since[node] >= 0.0:
                                        c_on_ivals.append(
                                            (c_on_since[node], t))
                                        c_on_since[node] = -1.0
                        else:
                            t_srv_d += 1
                    # free the DSA and continue its queue, per scheduler
                    if sk == 0:
                        d_busy[d] = 0
                        if fa:
                            d_run[d] = -1
                        if d_queues[d]:
                            start_drive(d, t)
                    elif sk == 1:
                        d_cur[d] = -1
                        d_busy[d] = 0
                        ts_select(d, t)
                    else:
                        k = ten_l[rid]
                        sp_busy[d][k] = 0
                        if sp_q[d][k]:
                            sp_start(d, k, t)
                continue
            if next_t == INF:
                break
            # arrival (wins ties against dynamic events, like the PR-1 seq)
            t = next_t
            rid = ai
            if mt:
                tarr[ten_l[rid]] += 1
            if ov_on:
                # admission control fires before placement, deadlines and
                # hedging: a rejected arrival consumes no queue slot, no
                # sampler draw and no timer
                why = ov_admit(rid, t) if ov_gate_on else 0
                if why:
                    ov_rej += 1
                    if why == 1:
                        ov_rej_push += 1
                    else:
                        ov_rej_adm += 1
                    ov_rej_cls[0 if accel_l[rid] else 1] += 1
                    if mt:
                        ov_ten_rej[ten_l[rid]] += 1
                    dead_l[rid] = 1
                    if t > end_t:
                        end_t = t
                    ai += 1
                    if ai < n:
                        if ai == limit:
                            base = ai
                            limit = min(n, ai + _CHUNK)
                            times_l = times[ai:limit].tolist()
                        next_t = times_l[ai - base]
                    else:
                        next_t = INF
                    continue
                ov_admitted += 1
                ov_adm_cls[0 if accel_l[rid] else 1] += 1
                if mt:
                    ov_ten_adm[ten_l[rid]] += 1
            if timeout_s is not None:
                dl_dq.append((t + timeout_s, rid))
            if accel_l[rid]:
                if tier_on:
                    # replica routing: among the object's replica drives
                    # prefer powered, then least-loaded, then cache-warm
                    # (lowest drive index on ties).  Load outranks warmth:
                    # a cache hit saves ~ms while a queued copy costs a
                    # full service time, so warmth-first would pile every
                    # hot-key request back onto one drive and recreate
                    # exactly the hotspot replication exists to dissolve
                    if obj_l is not None:
                        o = obj_l[rid]
                        reps = replicas[o]
                    else:
                        o = rid
                        reps = replicas.get(o)
                        if reps is None:
                            reps = _hrw_ranking(f"req-{rid}", nd)[:t_k]
                            replicas[o] = reps
                            mat[reps[0]].add(o)
                    d = reps[0]
                    if len(reps) > 1 or fa:
                        best = None
                        for d2 in reps:
                            if fa and not d_alive[d2]:
                                continue    # route around dead drives
                            key2 = (1 if (dyn and not d_power[d2]) else 0,
                                    d_qd[d2] + d_busy[d2],
                                    0 if (caches is not None
                                          and caches[d2].warm(o)) else 1,
                                    d2)
                            if best is None or key2 < best:
                                best = key2; d = d2
                        if fa and best is None:
                            d = -1          # every replica is down
                    drive_l[rid] = d
                    if mig is not None and d >= 0:
                        a2 = acc[d]
                        a2[o] = a2.get(o, 0) + 1
                else:
                    d = drive_l[rid]
                    if fa and not d_alive[d]:
                        d = -1
                if fa and d < 0:
                    # no surviving drive holds the object: gracefully
                    # degrade to the CPU path + remote backing fetch
                    drive_l[rid] = -1
                    t_cdisp += 1
                    degrade(rid, t)
                    ai += 1
                    if ai < n:
                        if ai == limit:
                            base = ai
                            limit = min(n, ai + _CHUNK)
                            times_l = times[ai:limit].tolist()
                        next_t = times_l[ai - base]
                    else:
                        next_t = INF
                    continue
                if ov_maxq is not None and d_qd[d] >= ov_maxq:
                    # bounded drive queue: make room by shedding the
                    # oldest live queued copy, or drop the arrival itself
                    # (before any hedge/detect timer is enqueued)
                    if ov_incoming:
                        ds_l[rid] = _CANCELLED
                        ov_drop_incoming(rid, t)
                        ai += 1
                        if ai < n:
                            if ai == limit:
                                base = ai
                                limit = min(n, ai + _CHUNK)
                                times_l = times[ai:limit].tolist()
                            next_t = times_l[ai - base]
                        else:
                            next_t = INF
                        continue
                    ov_evict_drive(d, t)
                t_ddisp += 1
                if hedge is not None:
                    hedge_dq.append((t + hedge, rid))
                if det_s is not None:
                    det_dq.append((t + det_s, rid))
                if sk == 1:
                    # time-slicing: enqueue on the owning tenant's
                    # per-drive queue; kick the scheduler if the DSA idles
                    k = ten_l[rid]
                    d_area[d] += d_qd[d] * (t - d_last[d]); d_last[d] = t
                    d_tq[d][k].append(rid)
                    q = d_qd[d] + 1; d_qd[d] = q
                    if q > d_maxd[d]: d_maxd[d] = q
                    tacct_d(k, t, 1)
                    ds_l[rid] = _QUEUED
                    if d_cur[d] < 0:
                        ts_select(d, t)
                elif sk == 2:
                    # spatial partitioning: the tenant's own lane group
                    k = ten_l[rid]
                    if sp_busy[d][k] or sp_q[d][k]:
                        d_area[d] += d_qd[d] * (t - d_last[d]); d_last[d] = t
                        sp_q[d][k].append(rid)
                        q = d_qd[d] + 1; d_qd[d] = q
                        if q > d_maxd[d]: d_maxd[d] = q
                        tacct_d(k, t, 1)
                        ds_l[rid] = _QUEUED
                    else:
                        sp_start_new(d, k, rid, t)
                else:
                    if dyn and d_power[d] == 0:
                        # data lives on a powered-off drive: start its wake
                        # (serviceable after dscs_wake_s) and queue the
                        # request there; marking the drive busy routes this
                        # and any later arrivals through the normal queue
                        # path below
                        d_power[d] = 2
                        n_d_on += 1
                        n_waking += 1
                        d_on_since[d] = t
                        d_busy[d] = 1
                        hpush(heap, (t + wake_s, -(d + 1)))
                        t_wake += 1
                    if d_busy[d] or d_queues[d]:
                        d_area[d] += d_qd[d] * (t - d_last[d]); d_last[d] = t
                        d_queues[d].append(rid)
                        q = d_qd[d] + 1; d_qd[d] = q
                        if q > d_maxd[d]: d_maxd[d] = q
                        ds_l[rid] = _QUEUED
                        if mt:
                            tacct_d(ten_l[rid], t, 1)
                        # a server only goes idle by draining its deque to
                        # empty (discarding tombstones), so nonempty deque
                        # => busy
                        assert d_busy[d], "idle drive held a nonempty queue"
                    else:
                        # idle drive: start immediately (transient depth 1)
                        d_last[d] = t
                        if not d_maxd[d]: d_maxd[d] = 1
                        ds_l[rid] = _RUNNING
                        i = s_i
                        if i == len(s_tr):
                            s_grow()
                        s_i = i + 1
                        c = coef_d[picks_l[rid]]
                        svc = c[0] + c[1] * s_tr[i] + c[2] * s_tw[i]
                        if tier_on:
                            svc = tier_adjust(rid, d, svc)
                        if fa:
                            sf = d_stall[d]
                            if sf != 1.0:
                                svc *= sf
                            d_run[d] = rid
                        d_busy_s += svc
                        d_start_a[rid] = t; d_svc_a[rid] = svc
                        d_busy[d] = 1
                        if mt:
                            tb_d[ten_l[rid]] += svc
                        hpush(heap, (t + svc, rid << 1))
            else:
                issue_cpu(rid, t)
                t_cdisp += 1
            ai += 1
            if ai < n:
                if ai == limit:
                    base = ai
                    limit = min(n, ai + _CHUNK)
                    times_l = times[ai:limit].tolist()
                next_t = times_l[ai - base]
            else:
                next_t = INF
        # every enqueued hedge timer is eventually popped and every started
        # copy (= one sampler draw) reaches a terminal event, so the count
        # is exact (quantum expiries counted separately)
        events = (n + (s_i - sampler._i)
                  + (t_ddisp if hedge is not None else 0) + t_wake + t_pre
                  + x_ev)
        sampler._i = s_i                # keep the sampler cursor consistent

        # -- power accounting (busy/powered seconds per class) ---------------
        if dyn:
            # clip every powered interval to the common horizon: epochs can
            # fire past the last completion (stale hedge timers, pending
            # wakes), and neither a power-off there nor a still-open
            # interval may contribute powered time beyond end_t
            c_on_s = sum(max(0.0, min(b, end_t) - a) for a, b in c_on_ivals)
            d_on_s = sum(max(0.0, min(b, end_t) - a) for a, b in d_on_ivals)
            for ts0 in c_on_since:
                if ts0 >= 0.0:
                    c_on_s += max(0.0, end_t - ts0)
            for ts0 in d_on_since:
                if ts0 >= 0.0:
                    d_on_s += max(0.0, end_t - ts0)
        else:
            c_on_s = end_t * nc
            d_on_s = end_t * nd
        self._pstate = {
            "horizon": end_t,
            "dscs": {"busy_s": d_busy_s, "powered_s": d_on_s, "n": nd},
            "cpu": {"busy_s": c_busy_s, "powered_s": c_on_s, "n": nc},
            "wake_events": t_wake, "epochs": ep_idx}

        # -- fault & deadline telemetry --------------------------------------
        # surfaced whenever any of faults / timeout / overload is enabled:
        # a timeout- or overload-only run must not silently lose its
        # abandonment and rejection counts just because no FaultPlan is set
        if fa or timeout_s is not None or ov_on:
            completed = t_srv_d + t_srv_c + t_won_d + t_won_c
            if fa:
                for d in range(nd):
                    if d_down_since[d] >= 0.0:  # still down at the horizon
                        down = end_t - d_down_since[d]
                        if down > 0.0:
                            d_down_s[d] += down
                self._fstate = {
                    "enabled": True,
                    "injected": {
                        "drive_fail": f_inj[DRIVE_FAIL],
                        "drive_recover": f_inj[DRIVE_RECOVER],
                        "stall": f_inj[STALL_BEGIN],
                        "cpu_crash": f_inj[CPU_CRASH],
                        "cpu_recover": f_inj[CPU_RECOVER],
                        "cpu_crash_skipped": f_cpu_skip,
                        "backing_fetch_failures": f_back_fail,
                    },
                    "lost": f_lost,
                    "retries": {"scheduled": f_retry_sched,
                                "redispatched": f_redisp,
                                "budget_denied": f_budget_deny},
                    "abandoned": f_aband,
                    "deadline_abandoned": t_dead,
                    "rejected": ov_rej,
                    "shed": ov_shed,
                    "degraded": f_degraded,
                    "detect_hedges": f_detect,
                    "unavailability": {"per_drive_s": list(d_down_s),
                                       "total_s": sum(d_down_s)},
                    "repair": {"bytes": rep_bytes, "seconds": rep_s,
                               "jobs": rep_jobs, "objects": rep_objs},
                    "goodput": {"offered": n, "completed": completed,
                                "goodput_frac": (completed / n
                                                 if n else 0.0)},
                }
                for nm2, v2 in (("fault_lost", f_lost),
                                ("fault_retries", f_retry_sched),
                                ("fault_abandoned", f_aband),
                                ("fault_degraded", f_degraded),
                                ("fault_detect_hedges", f_detect),
                                ("repair_bytes", rep_bytes),
                                ("repair_s", rep_s)):
                    if v2:
                        self.telemetry.inc(nm2, v2)
            else:
                self._fstate = {
                    "enabled": False,
                    "abandoned": 0,
                    "deadline_abandoned": t_dead,
                    "rejected": ov_rej,
                    "shed": ov_shed,
                    "goodput": {"offered": n, "completed": completed,
                                "goodput_frac": (completed / n
                                                 if n else 0.0)},
                }
            if t_dead:
                self.telemetry.inc("deadline_abandoned", t_dead)

        # -- overload-control telemetry --------------------------------------
        if ov_on:
            if bro_active:
                bro_ivals.append((bro_since, end_t))
            self._ovstate = {
                "enabled": True,
                "admitted": ov_admitted,
                "rejected": ov_rej,
                "shed": ov_shed,
                "copies_cancelled": ov_cc,
                "rejected_by": {"pushback": ov_rej_push,
                                "admission": ov_rej_adm},
                "shed_by": {"bounded": ov_shed_by[0],
                            "hopeless": ov_shed_by[1],
                            "codel": ov_shed_by[2]},
                "per_class": {
                    "accel": {"admitted": ov_adm_cls[0],
                              "rejected": ov_rej_cls[0],
                              "shed": ov_shed_cls[0]},
                    "plain": {"admitted": ov_adm_cls[1],
                              "rejected": ov_rej_cls[1],
                              "shed": ov_shed_cls[1]},
                },
                "per_tenant": ({
                    "names": [ten.name for ten in tenants],
                    "admitted": ov_ten_adm,
                    "rejected": ov_ten_rej,
                    "shed": ov_ten_shed,
                } if mt else None),
                "retries_denied": ov_retry_deny,
                "hedges_suppressed": ov_hedge_sup,
                "brownout": {"entered": bro_entered,
                             "active_epochs": bro_ep_act,
                             "intervals": bro_ivals},
                "pushback": {"timeline": push_tl, "final": push_f},
                "epochs": ov_epochs,
                "goodput": {"offered": n, "completed": completed,
                            "goodput_frac": (completed / n
                                             if n else 0.0)},
            }
            for nm2, v2 in (("overload_rejected", ov_rej),
                            ("overload_shed", ov_shed),
                            ("overload_retries_denied", ov_retry_deny),
                            ("overload_hedges_suppressed", ov_hedge_sup)):
                if v2:
                    self.telemetry.inc(nm2, v2)

        # -- per-tenant telemetry (finalized to the common horizon) ----------
        if mt:
            for k in range(K):
                tqa_d[k] += tqd_d[k] * (end_t - tql_d[k]); tql_d[k] = end_t
                tqa_c[k] += tqd_c[k] * (end_t - tql_c[k]); tql_c[k] = end_t
            hz = end_t
            self._tstate = {
                "horizon": hz,
                "scheduler": sched.name,
                "names": [ten.name for ten in tenants],
                "sla_s": [ten.sla_s for ten in tenants],
                "weight": [ten.weight for ten in tenants],
                "arrivals": tarr,
                "completions": tdone,
                "busy_dscs_s": tb_d,
                "busy_cpu_s": tb_c,
                "queue": {
                    "dscs": {"mean_depth": [a / hz if hz > 0 else 0.0
                                            for a in tqa_d],
                             "max_depth": [float(v) for v in tqm_d]},
                    "cpu": {"mean_depth": [a / hz if hz > 0 else 0.0
                                           for a in tqa_c],
                            "max_depth": [float(v) for v in tqm_c]},
                },
                "switch_overhead_s": t_switch_s,
                "reclaimed_dscs_s": rec_d,
                "reclaimed_cpu_s": rec_c,
            }
        else:
            self._tstate = None

        # -- tiered data-layer telemetry -------------------------------------
        if tier_on:
            cs = [c.stats() for c in caches] if caches is not None else []
            hits = sum(s["hits"] for s in cs)
            misses = sum(s["misses"] for s in cs)
            self._tierstate = {
                "replication_k": t_k,
                "n_objects": t_nobj if t_nobj else n,
                "cache_bytes": tier.cache_bytes,
                "cache": {
                    "hits": hits, "misses": misses,
                    "hit_rate": (hits / (hits + misses)
                                 if hits + misses else 0.0),
                    "evictions": sum(s["evictions"] for s in cs),
                    "per_drive": cs,
                },
                "backing_fetches": t_fill,
                "backing_s": fill_s,
                "migration": (None if mig is None else
                              {"moves": mig.moves, "epochs": mig.epochs,
                               "log": list(mig.log)}),
            }
            for nm, v in (("cache_hits", hits), ("cache_misses", misses),
                          ("backing_fetches", t_fill),
                          ("backing_fetch_s", fill_s),
                          ("migration_moves",
                           0 if mig is None else mig.moves)):
                if v:
                    self.telemetry.inc(nm, v)

        # -- flush telemetry -------------------------------------------------
        inc = self.telemetry.inc
        for name, v in (("dscs_dispatch", t_ddisp), ("cpu_dispatch", t_cdisp),
                        ("hedge_issued", t_hedge), ("dscs_fallback", t_hedge),
                        ("hedge_won_dscs", t_won_d), ("hedge_won_cpu", t_won_c),
                        ("dscs_served", t_srv_d), ("cpu_served", t_srv_c),
                        ("cancelled_in_queue", t_can_q),
                        ("cancelled_in_service", t_can_s),
                        ("tombstones_discarded", t_tomb),
                        ("reclaimed_dscs_s", rec_d),
                        ("reclaimed_cpu_s", rec_c),
                        ("ts_switch_overhead_s", t_switch_s),
                        ("ts_preemptions", t_pre)):
            if v:
                inc(name, v)

        # queue telemetry, finalized to the common end-of-run horizon
        self._qstate = {"horizon": end_t,
                        "dscs": (d_area, d_maxd), "cpu": (c_area, c_maxd),
                        "tombstones_discarded": t_tomb,
                        "cancelled_in_queue": t_can_q}

        # -- assemble the trace ---------------------------------------------
        def as_np(a: array) -> np.ndarray:
            return (np.frombuffer(a, dtype=np.float64) if n
                    else np.empty(0, dtype=np.float64))

        winner_np = np.array(winner_l, dtype=np.int8)
        drive_np = np.array(drive_l, dtype=np.int32)
        dscs_won = winner_np == 0
        return EngineTrace(
            arrival=times, finish=as_np(finish_a), winner=winner_np,
            drive=np.where(dscs_won, drive_np, -1).astype(np.int32),
            start=np.where(dscs_won, as_np(d_start_a), as_np(c_start_a)),
            service=np.where(dscs_won, as_np(d_svc_a), as_np(c_svc_a)),
            hedged=np.array(hedged_l, dtype=bool),
            dscs_finish=as_np(dfin_a), cpu_finish=as_np(cfin_a),
            events=events,
            tenant=(src if mt else np.zeros(n, dtype=np.int32)))

    # -- sharded execution ---------------------------------------------------
    def run_sharded(self, pipelines: Optional[Sequence[Pipeline]] = None, *,
                    arrivals: Optional[ArrivalProcess] = None,
                    duration_s: float = 0.0,
                    times: Optional[np.ndarray] = None,
                    n_shards: int = 1,
                    processes: Optional[int] = None,
                    timeout_s: Optional[float] = None,
                    epoch_count: int = 64,
                    mailbox_capacity: Optional[int] = None,
                    backend: str = "segmented",
                    overload: Optional[OverloadControl] = None
                    ) -> EngineTrace:
        """Run the fleet sharded by drive partition across workers.

        ``n_shards=1`` runs the classic event loop — byte-for-byte the
        same trace :meth:`run_soa` produces (the golden-trace stream).
        With ``n_shards >= 2`` the fleet is split into disjoint drive
        partitions (plus weighted CPU slices) executed by
        :mod:`repro.core.sharding`: shard-count- and process-count-
        independent on the fault-free fast path, shard-isolated classic
        loops under faults/tiering/deadlines.  ``processes`` bounds the
        worker pool (default: one per shard up to the core count;
        ``processes=1`` runs the shards serially in-process with
        identical results).  ``epoch_count`` and ``mailbox_capacity``
        tune the bounded cross-shard mailbox.  Multi-tenant runs are not
        supported sharded — use ``n_shards=1``.  ``backend`` selects the
        fast path's Lindley solver (``segmented``/``pallas``/``dense``,
        see :mod:`repro.core.lindley` — all bit-identical; ``n_shards=1``
        and the shard-isolated fallback run the classic event loop and
        ignore it).
        """
        if n_shards == 1:
            return self.run_soa(pipelines, arrivals=arrivals,
                                duration_s=duration_s, times=times,
                                timeout_s=timeout_s, overload=overload)
        from repro.core.sharding import run_partitioned
        return run_partitioned(self, pipelines, arrivals=arrivals,
                               duration_s=duration_s, times=times,
                               n_shards=n_shards, processes=processes,
                               timeout_s=timeout_s, epoch_count=epoch_count,
                               mailbox_capacity=mailbox_capacity,
                               backend=backend, overload=overload)

    # -- telemetry -----------------------------------------------------------
    def queue_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-class queue-depth telemetry from the last run.

        Every server is finalized to the *common* end-of-run horizon (the
        time of the last event anywhere in the fleet), so servers of a
        class that idled early no longer skew ``mean_depth``.  A drained
        server holds depth 0 after its last event, so its depth integral is
        already complete; the shared horizon only fixes the denominator.
        """
        empty = {"max_depth": 0.0, "mean_depth": 0.0}
        if self._qstate is None:
            return {"dscs": dict(empty), "cpu": dict(empty)}
        horizon = self._qstate["horizon"]

        def summarize(area: List[float], maxd: List[int]) -> Dict[str, float]:
            if not area:
                return dict(empty)
            mean = sum(area) / (horizon * len(area)) if horizon > 0 else 0.0
            return {"max_depth": float(max(maxd)), "mean_depth": float(mean)}

        return {"dscs": summarize(*self._qstate["dscs"]),
                "cpu": summarize(*self._qstate["cpu"])}

    def power_stats(self) -> Dict[str, object]:
        """Busy/powered server-seconds per class from the last run.

        ``busy_s`` sums every started copy's service time (including
        run-to-completion hedge losers — they occupy their server);
        ``powered_s`` sums each server's powered-on intervals, clipped to
        the common end-of-run horizon.  Without an autoscaling controller
        the whole provisioned fleet is powered for the whole run, so
        ``powered_s = horizon * n``.  :mod:`repro.core.autoscale` turns
        these into fleet energy and cost.
        """
        if self._pstate is None:
            zero = {"busy_s": 0.0, "powered_s": 0.0, "n": 0}
            return {"horizon": 0.0, "dscs": dict(zero), "cpu": dict(zero),
                    "wake_events": 0, "epochs": 0}
        return self._pstate

    def tier_stats(self) -> Optional[Dict[str, object]]:
        """Tiered data-layer telemetry from the last run (``None`` when the
        tier was absent or disabled).

        Keys: ``replication_k`` (effective factor), ``n_objects``,
        ``cache_bytes``; ``cache`` with aggregate ``hits``/``misses``/
        ``hit_rate``/``evictions`` plus ``per_drive`` stat dicts;
        ``backing_fetches``/``backing_s`` (lazy replica + migration fills
        from the remote backing store); and ``migration`` (``None`` without
        a controller, else its ``moves``/``epochs`` counters and the
        ``(t, obj, from, to)`` move ``log``).
        """
        return self._tierstate

    def fault_stats(self) -> Optional[Dict[str, object]]:
        """Fault-injection & recovery telemetry from the last run
        (``None`` when neither a :class:`~repro.core.faults.FaultPlan`
        nor a ``timeout_s`` deadline was configured).

        With a plan: ``injected`` (timeline events applied per kind, plus
        ``cpu_crash_skipped`` last-live-node vetoes and
        ``backing_fetch_failures``), ``lost`` (copies killed with no
        sibling copy racing), ``retries``
        (``scheduled``/``redispatched``/``budget_denied``), ``abandoned``
        (retry-path give-ups), ``deadline_abandoned``, ``degraded``
        (requests served CPU + backing fetch because no live drive held
        their object), ``detect_hedges`` (watchdog-issued CPU copies),
        ``unavailability`` (``per_drive_s`` down-seconds clipped to the
        horizon and their ``total_s``), ``repair``
        (``bytes``/``seconds``/``jobs``/``objects`` re-replicated), and
        ``goodput`` (``offered``/``completed``/``goodput_frac``).  With
        only ``timeout_s`` (or an overload layer), the dict carries
        ``abandoned``/``deadline_abandoned``/``rejected``/``shed`` and
        ``goodput``.
        """
        return self._fstate

    def overload_stats(self) -> Optional[Dict[str, object]]:
        """Overload-control telemetry from the last run (``None`` when no
        :class:`~repro.core.overload.OverloadControl` was active).

        Keys: ``admitted``/``rejected``/``shed`` request counts with
        ``rejected_by`` (``pushback``/``admission``) and ``shed_by``
        (``bounded``/``hopeless``/``codel``) breakdowns;
        ``copies_cancelled`` (copy-level sheds whose request survived on a
        sibling copy); ``per_class`` (accel/plain) and ``per_tenant``
        books; ``retries_denied`` (retry attempts refused by the admission
        gate) and ``hedges_suppressed`` (hedge timers swallowed by
        brownout); ``brownout`` (``entered``/``active_epochs`` and the
        ``(start, stop)`` ``intervals``); ``pushback`` (the ``(t, factor)``
        change ``timeline`` — replayable open-loop through
        :class:`~repro.core.overload.ThrottledArrivals` — and the
        ``final`` factor); ``epochs``; and ``goodput``.
        """
        return self._ovstate

    def tenant_stats(self) -> Optional[Dict[str, object]]:
        """Per-tenant telemetry from the last multi-tenant run (``None``
        after single-tenant runs).

        Keys: ``horizon`` (common end-of-run time every depth integral is
        finalized to), ``scheduler``, and per-tenant parallel lists
        indexed by tenant — ``names``/``sla_s``/``weight`` echo the specs;
        ``arrivals``/``completions`` are request counts;
        ``busy_dscs_s``/``busy_cpu_s`` are consumed service-seconds per
        class (time-slice context-switch overhead is charged to the
        incoming tenant); ``queue`` holds per-class
        ``mean_depth``/``max_depth`` of the tenant's live queued copies
        fleet-wide (mean is the depth integral over the common horizon).
        ``switch_overhead_s`` and ``reclaimed_dscs_s``/``reclaimed_cpu_s``
        are run-level scalars.
        """
        return self._tstate
