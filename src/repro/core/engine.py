"""Discrete-event cluster engine (§V scheduler, §VI-C straggler study).

A genuine event-driven simulator of the extended Kubernetes scheduler from
the paper, replacing the per-node "next-free clock" approximation that used
to live in ``scheduler.py``.  The event model:

  * a binary heap of ``_Event``s, three kinds:
      - ``arrival``  — a request enters the system (times come from a
        pluggable :mod:`repro.core.arrivals` process)
      - ``finish``   — a running copy completes service on its node
      - ``hedge``    — the hedge timer for a queued acceleratable request
        expires
  * **data-aware placement** — each acceleratable request's payload is
    placed through :class:`repro.core.placement.StoragePool` (deterministic
    hash spread over ``Acceleratable_Storage`` drives) and the request is
    dispatched to the DSCS drive that *holds* its object, never a uniform
    random draw.  Each drive runs a FCFS, run-to-completion queue (no DSA
    multi-tenancy, §V) with queue-depth telemetry.
  * **real hedged dispatch** — if an acceleratable request is still queued
    ``hedge_budget_s`` after arrival, a second copy is issued on the
    least-loaded CPU node.  Both copies race; the first finisher wins and
    the loser is cancelled: a still-queued loser is removed from its queue
    (consumes no service), while an already-running loser runs to
    completion occupying its node (run-to-completion — no preemption) and
    its result is discarded.  ``RequestResult`` records ``hedged``,
    ``winner`` and both finish times so tail-latency attribution (Fig. 16)
    is observable.

Every stochastic choice — pipeline sampling, service-time tails (drawn by
quantile inversion through ``LatencyModel.e2e(q=u)``) and the arrival
stream — derives from the single engine seed, so a run is exactly
reproducible and two engines with equal seeds emit identical
``RequestResult`` streams.
"""
from __future__ import annotations

import heapq
import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.function import Pipeline
from repro.core.latency import LatencyModel, _erfinv
from repro.core.placement import StoragePool
from repro.core.platforms import PLATFORMS


@dataclass
class Telemetry:
    """Prometheus-analogue counters (shared with the scheduler façade)."""
    counters: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    def inc(self, name: str, v: float = 1.0) -> None:
        self.counters[name] += v

    def get(self, name: str) -> float:
        return self.counters[name]


class _ServiceCache:
    """Closed-form service-time sampler.

    ``LatencyModel.pipeline_breakdown`` at quantile ``q`` decomposes as
    ``A + R*Tr(q) + W*Tw(q)`` — a deterministic part plus the summed
    network-read/-write bases scaled by their shared lognormal quantile
    multipliers.  Solving that 3x3 system once per (workload, platform)
    turns every per-request draw into two ``exp`` calls instead of a full
    breakdown (~400x faster), which is what makes the throughput binary
    search affordable at fleet scale.

    Modeling note: a single uniform draw ``u`` drives every tail multiplier
    of a request comonotonically (all reads and writes are slow together),
    whereas the pre-engine scheduler sampled each network component
    independently.  The comonotone total has a somewhat fatter tail than
    the independent sum, so absolute p99/SLA numbers shift slightly versus
    the seed model; within-experiment comparisons (hedging on/off, arrival
    shapes, fleet ratios) are unaffected.
    """

    def __init__(self, lm: LatencyModel):
        self.lm = lm
        self._coef: Dict[tuple, np.ndarray] = {}

    def _tails(self, q: float) -> tuple:
        z = math.sqrt(2.0) * _erfinv(2.0 * q - 1.0)
        return (math.exp(self.lm.params.read_sigma * z),
                math.exp(self.lm.params.write_sigma * z))

    def __call__(self, pipe: Pipeline, platform: str, u: float) -> float:
        # service time depends only on (workload, platform); Workload is a
        # frozen dataclass, so this key is stable (unlike id()) and shared
        # across pipeline variants of the same workload
        key = (pipe.workload, platform)
        coef = self._coef.get(key)
        if coef is None:
            plat = PLATFORMS[platform]
            qs = (0.5, 0.84, 0.975)
            rows = [(1.0,) + self._tails(q) for q in qs]
            e2e = [self.lm.e2e(plat, pipe.workload, q=q) for q in qs]
            # lstsq, not solve: with read_sigma == write_sigma the Tr and Tw
            # columns coincide and the system is rank-2; the minimum-norm
            # solution still reproduces e2e(q) exactly
            coef = np.linalg.lstsq(np.array(rows), np.array(e2e),
                                   rcond=None)[0]
            self._coef[key] = coef
        tr, tw = self._tails(u)
        return float(coef[0] + coef[1] * tr + coef[2] * tw)


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: object = field(compare=False, default=None)


@dataclass
class RequestResult:
    """One completed request.  ``finish``/``accelerated`` describe the
    winning copy; for hedged requests both per-path finish times are kept
    (the loser's is back-filled when its run-to-completion copy drains, and
    stays ``None`` if it was cancelled while still queued)."""
    arrival: float
    finish: float
    accelerated: bool
    hedged: bool = False
    winner: str = ""                    # "dscs" | "cpu"
    drive: int = -1                     # serving DSCS drive index, -1 = CPU
    start: float = 0.0                  # winning copy's service start
    service: float = 0.0                # winning copy's service duration
    dscs_finish: Optional[float] = None
    cpu_finish: Optional[float] = None

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    @property
    def queue_wait(self) -> float:
        return self.start - self.arrival


class _Copy:
    """One issued execution path of a request (DSCS or CPU)."""
    __slots__ = ("req", "path", "node", "state", "start", "service")

    def __init__(self, req: "_Req", path: str, node: int):
        self.req = req
        self.path = path                # "dscs" | "cpu"
        self.node = node
        self.state = "queued"           # queued | running | done | cancelled
        self.start = 0.0
        self.service = 0.0


class _Req:
    __slots__ = ("rid", "arrival", "pipe", "accel", "drive", "copies",
                 "hedged", "result")

    def __init__(self, rid: int, arrival: float, pipe: Pipeline):
        self.rid = rid
        self.arrival = arrival
        self.pipe = pipe
        self.accel = False
        self.drive = -1
        self.copies: Dict[str, _Copy] = {}
        self.hedged = False
        self.result: Optional[RequestResult] = None


class _Server:
    """Single-server FCFS queue with time-weighted depth accounting."""
    __slots__ = ("queue", "running", "depth_area", "max_depth", "_last_t")

    def __init__(self):
        self.queue: List[_Copy] = []
        self.running: Optional[_Copy] = None
        self.depth_area = 0.0           # integral of queue depth over time
        self.max_depth = 0
        self._last_t = 0.0

    def _account(self, t: float) -> None:
        self.depth_area += len(self.queue) * (t - self._last_t)
        self._last_t = t

    def push(self, copy: _Copy, t: float) -> None:
        self._account(t)
        self.queue.append(copy)
        self.max_depth = max(self.max_depth, len(self.queue))

    def cancel_queued(self, copy: _Copy, t: float) -> None:
        self._account(t)
        self.queue.remove(copy)

    def pop(self, t: float) -> Optional[_Copy]:
        if self.running is not None or not self.queue:
            return None
        self._account(t)
        return self.queue.pop(0)

    @property
    def load(self) -> int:
        return len(self.queue) + (1 if self.running is not None else 0)


class ClusterEngine:
    """The discrete-event fleet: ``n_dscs`` DSCS drives with per-drive FCFS
    queues + ``n_cpu`` CPU fallback nodes, fed by an arrival process."""

    def __init__(self, *, n_dscs: int, n_cpu: int,
                 latency_model: Optional[LatencyModel] = None,
                 hedge_budget_s: Optional[float] = None, seed: int = 0,
                 n_plain: int = 64,
                 telemetry: Optional[Telemetry] = None):
        if n_cpu <= 0:
            raise ValueError("the fleet needs at least one CPU fallback node")
        self.n_dscs = n_dscs
        self.n_cpu = n_cpu
        self.n_plain = n_plain
        self.lm = latency_model or LatencyModel(seed=seed)
        self.hedge_budget_s = hedge_budget_s
        self.seed = seed
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.drives: List[_Server] = []
        self.cpus: List[_Server] = []
        self._svc_cache = _ServiceCache(self.lm)

    # -- service-time draws --------------------------------------------------
    def _service(self, pipe: Pipeline, platform: str,
                 rng: np.random.Generator) -> float:
        """Sample a service time by quantile inversion: a uniform draw from
        the engine's own rng is fed to the deterministic quantile path of
        the latency model (via the cached decomposition), so samples never
        touch ``LatencyModel.rng`` and the run is reproducible from the
        engine seed alone."""
        u = float(np.clip(rng.uniform(), 1e-4, 1.0 - 1e-4))
        return self._svc_cache(pipe, platform, u)

    # -- main loop -----------------------------------------------------------
    def run(self, pipelines: List[Pipeline], *, arrivals: ArrivalProcess,
            duration_s: float) -> List[RequestResult]:
        """Simulate ``duration_s`` of offered load and drain every request;
        returns one ``RequestResult`` per arrival, in arrival order."""
        ss = np.random.SeedSequence(self.seed)
        arr_rng, rng = (np.random.default_rng(s) for s in ss.spawn(2))
        pool = StoragePool(n_plain=self.n_plain, n_dscs=self.n_dscs)
        drive_idx = {d.drive_id: i for i, d in enumerate(pool.dscs_drives())}
        self.drives = [_Server() for _ in range(self.n_dscs)]
        self.cpus = [_Server() for _ in range(self.n_cpu)]

        heap: List[_Event] = []
        seq = 0

        def push(t: float, kind: str, payload) -> None:
            nonlocal seq
            seq += 1
            heapq.heappush(heap, _Event(t, seq, kind, payload))

        times = arrivals.times(duration_s, arr_rng)
        reqs: List[_Req] = []
        for rid, t in enumerate(map(float, times)):
            pipe = pipelines[int(rng.integers(len(pipelines)))]
            reqs.append(_Req(rid, t, pipe))
            push(t, "arrival", reqs[-1])

        while heap:
            ev = heapq.heappop(heap)
            if ev.kind == "arrival":
                self._on_arrival(ev.payload, ev.time, pool, drive_idx,
                                 rng, push)
            elif ev.kind == "hedge":
                self._on_hedge(ev.payload, ev.time, rng, push)
            else:                       # finish
                self._on_finish(ev.payload, ev.time, rng, push)

        return [r.result for r in reqs]

    # -- event handlers ------------------------------------------------------
    def _on_arrival(self, req: _Req, t: float, pool: StoragePool,
                    drive_idx: Dict[int, int], rng, push) -> None:
        req.accel = (self.n_dscs > 0
                     and all(f.acceleratable for f in req.pipe.functions[:2]))
        if req.accel:
            # data-aware placement: the payload is written to an
            # Acceleratable_Storage drive at arrival; the request is then
            # dispatched to the drive that holds it.
            drive = pool.place(f"req-{req.rid}", req.pipe.workload.request_bytes,
                               "Acceleratable_Storage")
            req.drive = drive_idx[drive.drive_id]
            copy = _Copy(req, "dscs", req.drive)
            req.copies["dscs"] = copy
            self.drives[req.drive].push(copy, t)
            self.telemetry.inc("dscs_dispatch")
            if self.hedge_budget_s is not None:
                push(t + self.hedge_budget_s, "hedge", req)
            self._maybe_start(self.drives[req.drive], t, rng, push)
        else:
            self._issue_cpu(req, t, rng, push)
            self.telemetry.inc("cpu_dispatch")

    def _issue_cpu(self, req: _Req, t: float, rng, push) -> None:
        node = min(range(self.n_cpu), key=lambda i: (self.cpus[i].load, i))
        copy = _Copy(req, "cpu", node)
        req.copies["cpu"] = copy
        self.cpus[node].push(copy, t)
        self._maybe_start(self.cpus[node], t, rng, push)

    def _on_hedge(self, req: _Req, t: float, rng, push) -> None:
        dscs = req.copies.get("dscs")
        if dscs is None or dscs.state != "queued" or req.result is not None:
            return                      # started or finished in time: no hedge
        req.hedged = True
        self.telemetry.inc("hedge_issued")
        self.telemetry.inc("dscs_fallback")   # budget blown -> CPU path opens
        self._issue_cpu(req, t, rng, push)

    def _on_finish(self, copy: _Copy, t: float, rng, push) -> None:
        server = (self.drives if copy.path == "dscs" else self.cpus)[copy.node]
        server.running = None
        req = copy.req
        if copy.state == "cancelled":
            # run-to-completion loser draining; back-fill its finish time
            if req.result is not None:
                self._record_path_finish(req.result, copy.path, t)
        else:
            copy.state = "done"
            if req.result is None:
                self._record_win(req, copy, t)
            self._record_path_finish(req.result, copy.path, t)
        self._maybe_start(server, t, rng, push)

    def _record_win(self, req: _Req, copy: _Copy, t: float) -> None:
        req.result = RequestResult(
            arrival=req.arrival, finish=t, accelerated=copy.path == "dscs",
            hedged=req.hedged, winner=copy.path,
            drive=req.drive if copy.path == "dscs" else -1,
            start=copy.start, service=copy.service)
        self.telemetry.inc(f"hedge_won_{copy.path}" if req.hedged
                           else f"{copy.path}_served")
        loser = req.copies.get("cpu" if copy.path == "dscs" else "dscs")
        if loser is None or loser.state in ("done", "cancelled"):
            return
        if loser.state == "queued":
            lsrv = (self.drives if loser.path == "dscs"
                    else self.cpus)[loser.node]
            lsrv.cancel_queued(loser, t)
            self.telemetry.inc("cancelled_in_queue")
        else:                           # running: no preemption, drains
            self.telemetry.inc("cancelled_in_service")
        loser.state = "cancelled"

    @staticmethod
    def _record_path_finish(res: Optional[RequestResult], path: str,
                            t: float) -> None:
        if res is None:
            return
        if path == "dscs" and res.dscs_finish is None:
            res.dscs_finish = t
        elif path == "cpu" and res.cpu_finish is None:
            res.cpu_finish = t

    def _maybe_start(self, server: _Server, t: float, rng, push) -> None:
        while True:
            copy = server.pop(t)
            if copy is None:
                return
            if copy.state == "cancelled":   # defensive: cancelled are removed
                continue
            copy.state = "running"
            copy.start = t
            plat = "DSCS-Serverless" if copy.path == "dscs" else "Baseline-CPU"
            copy.service = self._service(copy.req.pipe, plat, rng)
            server.running = copy
            push(t + copy.service, "finish", copy)
            return

    # -- telemetry -----------------------------------------------------------
    def queue_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-class queue-depth telemetry from the last run."""
        def summarize(servers: List[_Server]) -> Dict[str, float]:
            if not servers:
                return {"max_depth": 0.0, "mean_depth": 0.0}
            horizon = max((s._last_t for s in servers), default=0.0)
            mean = (sum(s.depth_area for s in servers)
                    / (horizon * len(servers))) if horizon > 0 else 0.0
            return {"max_depth": float(max(s.max_depth for s in servers)),
                    "mean_depth": float(mean)}
        return {"dscs": summarize(self.drives), "cpu": summarize(self.cpus)}
