"""Frozen pre-PR2 reference engine (golden-trace oracle + perf baseline).

This module preserves the PR-1 object-based discrete-event hot path —
``_Event`` dataclass heap holding every arrival up front, ``_Req``/``_Copy``
per-request objects, ``list``-backed FCFS queues with O(n) ``pop(0)`` /
``remove`` cancellation, and the O(n_cpu) least-loaded scan — exactly as it
shipped, so that:

  * the golden-trace tests can prove the optimized array-backed engine in
    :mod:`repro.core.engine` emits a bit-identical ``RequestResult`` stream
    seed-for-seed, and
  * ``benchmarks/bench_engine.py`` can measure real speedups against the
    pre-refactor baseline on any host.

The only change versus the shipped PR-1 code is that service-time draws go
through the shared :class:`repro.core.engine._ServiceSampler` (chunked,
numpy-vectorized quantile inversion) instead of per-draw ``math.exp`` —
both engines consume the *same* pre-transformed tail multipliers in the
same order, which is what makes bit-exact equivalence well-defined across
libm/SIMD implementations.  Draw *order* and every other simulation
semantic are untouched.  Do not optimize this module; it is the baseline.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.engine import (RequestResult, Telemetry,  # noqa: F401
                               _ServiceSampler)
from repro.core.function import Pipeline
from repro.core.latency import LatencyModel
from repro.core.placement import StoragePool


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: object = field(compare=False, default=None)


class _Copy:
    """One issued execution path of a request (DSCS or CPU)."""
    __slots__ = ("req", "path", "node", "state", "start", "service")

    def __init__(self, req: "_Req", path: str, node: int):
        self.req = req
        self.path = path                # "dscs" | "cpu"
        self.node = node
        self.state = "queued"           # queued | running | done | cancelled
        self.start = 0.0
        self.service = 0.0


class _Req:
    __slots__ = ("rid", "arrival", "pipe", "accel", "drive", "copies",
                 "hedged", "result")

    def __init__(self, rid: int, arrival: float, pipe: Pipeline):
        self.rid = rid
        self.arrival = arrival
        self.pipe = pipe
        self.accel = False
        self.drive = -1
        self.copies: Dict[str, _Copy] = {}
        self.hedged = False
        self.result: Optional[RequestResult] = None


class _Server:
    """Single-server FCFS queue with time-weighted depth accounting."""
    __slots__ = ("queue", "running", "depth_area", "max_depth", "_last_t")

    def __init__(self):
        self.queue: List[_Copy] = []
        self.running: Optional[_Copy] = None
        self.depth_area = 0.0           # integral of queue depth over time
        self.max_depth = 0
        self._last_t = 0.0

    def _account(self, t: float) -> None:
        self.depth_area += len(self.queue) * (t - self._last_t)
        self._last_t = t

    def push(self, copy: _Copy, t: float) -> None:
        self._account(t)
        self.queue.append(copy)
        self.max_depth = max(self.max_depth, len(self.queue))

    def cancel_queued(self, copy: _Copy, t: float) -> None:
        self._account(t)
        self.queue.remove(copy)

    def pop(self, t: float) -> Optional[_Copy]:
        if self.running is not None or not self.queue:
            return None
        self._account(t)
        return self.queue.pop(0)

    @property
    def load(self) -> int:
        return len(self.queue) + (1 if self.running is not None else 0)


class ReferenceClusterEngine:
    """The frozen PR-1 discrete-event fleet: ``n_dscs`` DSCS drives with
    per-drive FCFS queues + ``n_cpu`` CPU fallback nodes, fed by an arrival
    process.  Object-per-request, eager arrival heap, O(n) queue ops."""

    def __init__(self, *, n_dscs: int, n_cpu: int,
                 latency_model: Optional[LatencyModel] = None,
                 hedge_budget_s: Optional[float] = None, seed: int = 0,
                 n_plain: int = 64,
                 telemetry: Optional[Telemetry] = None):
        if n_cpu <= 0:
            raise ValueError("the fleet needs at least one CPU fallback node")
        self.n_dscs = n_dscs
        self.n_cpu = n_cpu
        self.n_plain = n_plain
        self.lm = latency_model or LatencyModel(seed=seed)
        self.hedge_budget_s = hedge_budget_s
        self.seed = seed
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.drives: List[_Server] = []
        self.cpus: List[_Server] = []
        self._sampler = _ServiceSampler(self.lm)

    # -- main loop -----------------------------------------------------------
    def run(self, pipelines: List[Pipeline], *, arrivals: ArrivalProcess,
            duration_s: float) -> List[RequestResult]:
        """Simulate ``duration_s`` of offered load and drain every request;
        returns one ``RequestResult`` per arrival, in arrival order."""
        ss = np.random.SeedSequence(self.seed)
        arr_rng, rng = (np.random.default_rng(s) for s in ss.spawn(2))
        self._sampler.start(rng)
        pool = StoragePool(n_plain=self.n_plain, n_dscs=self.n_dscs)
        drive_idx = {d.drive_id: i for i, d in enumerate(pool.dscs_drives())}
        self.drives = [_Server() for _ in range(self.n_dscs)]
        self.cpus = [_Server() for _ in range(self.n_cpu)]

        heap: List[_Event] = []
        seq = 0

        def push(t: float, kind: str, payload) -> None:
            nonlocal seq
            seq += 1
            heapq.heappush(heap, _Event(t, seq, kind, payload))

        times = arrivals.times(duration_s, arr_rng)
        reqs: List[_Req] = []
        for rid, t in enumerate(map(float, times)):
            pipe = pipelines[int(rng.integers(len(pipelines)))]
            reqs.append(_Req(rid, t, pipe))
            push(t, "arrival", reqs[-1])

        while heap:
            ev = heapq.heappop(heap)
            if ev.kind == "arrival":
                self._on_arrival(ev.payload, ev.time, pool, drive_idx,
                                 rng, push)
            elif ev.kind == "hedge":
                self._on_hedge(ev.payload, ev.time, rng, push)
            else:                       # finish
                self._on_finish(ev.payload, ev.time, rng, push)

        return [r.result for r in reqs]

    # -- event handlers ------------------------------------------------------
    def _on_arrival(self, req: _Req, t: float, pool: StoragePool,
                    drive_idx: Dict[int, int], rng, push) -> None:
        req.accel = (self.n_dscs > 0
                     and all(f.acceleratable for f in req.pipe.functions[:2]))
        if req.accel:
            # data-aware placement: the payload is written to an
            # Acceleratable_Storage drive at arrival; the request is then
            # dispatched to the drive that holds it.
            drive = pool.place(f"req-{req.rid}", req.pipe.workload.request_bytes,
                               "Acceleratable_Storage")
            req.drive = drive_idx[drive.drive_id]
            copy = _Copy(req, "dscs", req.drive)
            req.copies["dscs"] = copy
            self.drives[req.drive].push(copy, t)
            self.telemetry.inc("dscs_dispatch")
            if self.hedge_budget_s is not None:
                push(t + self.hedge_budget_s, "hedge", req)
            self._maybe_start(self.drives[req.drive], t, rng, push)
        else:
            self._issue_cpu(req, t, rng, push)
            self.telemetry.inc("cpu_dispatch")

    def _issue_cpu(self, req: _Req, t: float, rng, push) -> None:
        node = min(range(self.n_cpu), key=lambda i: (self.cpus[i].load, i))
        copy = _Copy(req, "cpu", node)
        req.copies["cpu"] = copy
        self.cpus[node].push(copy, t)
        self._maybe_start(self.cpus[node], t, rng, push)

    def _on_hedge(self, req: _Req, t: float, rng, push) -> None:
        dscs = req.copies.get("dscs")
        if dscs is None or dscs.state != "queued" or req.result is not None:
            return                      # started or finished in time: no hedge
        req.hedged = True
        self.telemetry.inc("hedge_issued")
        self.telemetry.inc("dscs_fallback")   # budget blown -> CPU path opens
        self._issue_cpu(req, t, rng, push)

    def _on_finish(self, copy: _Copy, t: float, rng, push) -> None:
        server = (self.drives if copy.path == "dscs" else self.cpus)[copy.node]
        server.running = None
        req = copy.req
        if copy.state == "cancelled":
            # run-to-completion loser draining; back-fill its finish time
            if req.result is not None:
                self._record_path_finish(req.result, copy.path, t)
        else:
            copy.state = "done"
            if req.result is None:
                self._record_win(req, copy, t)
            self._record_path_finish(req.result, copy.path, t)
        self._maybe_start(server, t, rng, push)

    def _record_win(self, req: _Req, copy: _Copy, t: float) -> None:
        req.result = RequestResult(
            arrival=req.arrival, finish=t, accelerated=copy.path == "dscs",
            hedged=req.hedged, winner=copy.path,
            drive=req.drive if copy.path == "dscs" else -1,
            start=copy.start, service=copy.service)
        self.telemetry.inc(f"hedge_won_{copy.path}" if req.hedged
                           else f"{copy.path}_served")
        loser = req.copies.get("cpu" if copy.path == "dscs" else "dscs")
        if loser is None or loser.state in ("done", "cancelled"):
            return
        if loser.state == "queued":
            lsrv = (self.drives if loser.path == "dscs"
                    else self.cpus)[loser.node]
            lsrv.cancel_queued(loser, t)
            self.telemetry.inc("cancelled_in_queue")
        else:                           # running: no preemption, drains
            self.telemetry.inc("cancelled_in_service")
        loser.state = "cancelled"

    @staticmethod
    def _record_path_finish(res: Optional[RequestResult], path: str,
                            t: float) -> None:
        if res is None:
            return
        if path == "dscs" and res.dscs_finish is None:
            res.dscs_finish = t
        elif path == "cpu" and res.cpu_finish is None:
            res.cpu_finish = t

    def _maybe_start(self, server: _Server, t: float, rng, push) -> None:
        while True:
            copy = server.pop(t)
            if copy is None:
                return
            if copy.state == "cancelled":   # defensive: cancelled are removed
                continue
            copy.state = "running"
            copy.start = t
            plat = "DSCS-Serverless" if copy.path == "dscs" else "Baseline-CPU"
            copy.service = self._sampler.draw(
                self._sampler.coef(copy.req.pipe.workload, plat))
            server.running = copy
            push(t + copy.service, "finish", copy)
            return

    # -- telemetry -----------------------------------------------------------
    def queue_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-class queue-depth telemetry from the last run.

        Kept with the PR-1 per-class horizon (``max _last_t`` of the class)
        including its known skew — the optimized engine finalizes every
        server to the common end-of-run horizon instead; only the
        ``RequestResult`` stream is golden-trace-gated."""
        def summarize(servers: List[_Server]) -> Dict[str, float]:
            if not servers:
                return {"max_depth": 0.0, "mean_depth": 0.0}
            horizon = max((s._last_t for s in servers), default=0.0)
            mean = (sum(s.depth_area for s in servers)
                    / (horizon * len(servers))) if horizon > 0 else 0.0
            return {"max_depth": float(max(s.max_depth for s in servers)),
                    "mean_depth": float(mean)}
        return {"dscs": summarize(self.drives), "cpu": summarize(self.cpus)}
