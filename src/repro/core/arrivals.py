"""Pluggable request-arrival processes for the cluster engine.

The fleet-level figures (Fig. 12 throughput-under-SLA, Fig. 16 straggler
mitigation) are sensitive to the *shape* of the offered load, not just its
mean rate.  This module provides the arrival processes the engine, the
benchmark sweeps and the examples share:

  * ``PoissonProcess``   — memoryless baseline (the paper's setting)
  * ``BurstyOnOff``      — 2-state MMPP: exponential ON/OFF phases with a
                           burst_factor rate multiplier while ON, calibrated
                           so the long-run mean rate equals ``rate``
  * ``DiurnalProcess``   — nonhomogeneous Poisson with a sinusoidal rate
                           profile (thinning / Lewis-Shedler sampling)
  * ``TraceReplay``      — deterministic replay of recorded arrival times
  * ``MergedArrivals``   — deterministic multiplexer of independent
                           component streams (one per tenant), with
                           per-arrival source attribution

Every process draws exclusively from the ``numpy.random.Generator`` handed
to :meth:`times`, so a single engine seed reproduces the full arrival
stream.  Processes are value objects: ``with_rate`` returns a rescaled copy
(used by the throughput binary search) without mutating the original.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, Tuple, Type

import numpy as np


@dataclass(frozen=True)
class ArrivalProcess:
    """Base class: a distribution over sorted arrival-time vectors."""
    rate: float                         # long-run mean requests/second

    def times(self, duration_s: float, rng: np.random.Generator) -> np.ndarray:
        """Sample one arrival stream: a sorted float64 vector of arrival
        times in ``[0, duration_s)``, drawn exclusively from ``rng`` (so
        one engine seed reproduces the full stream)."""
        raise NotImplementedError

    def with_rate(self, rate: float) -> "ArrivalProcess":
        """A copy of this process rescaled to a new mean rate."""
        return replace(self, rate=rate)


@dataclass(frozen=True)
class PoissonProcess(ArrivalProcess):
    """Homogeneous Poisson arrivals (i.i.d. exponential gaps)."""

    def times(self, duration_s: float, rng: np.random.Generator) -> np.ndarray:
        """Exponential-gap sampling, drawn in vectorized blocks."""
        if self.rate <= 0.0 or duration_s <= 0.0:
            return np.empty(0)
        # draw in blocks until we pass duration_s
        out = []
        t = 0.0
        block = max(16, int(self.rate * duration_s * 1.2))
        while t < duration_s:
            gaps = rng.exponential(1.0 / self.rate, size=block)
            ts = t + np.cumsum(gaps)
            out.append(ts)
            t = float(ts[-1])
        ts = np.concatenate(out)
        return ts[ts < duration_s]


@dataclass(frozen=True)
class BurstyOnOff(ArrivalProcess):
    """Markov-modulated Poisson process with ON bursts.

    While ON the instantaneous rate is ``burst_factor * rate``; the OFF rate
    is solved so the long-run mean equals ``rate`` given the duty cycle
    ``mean_on_s / (mean_on_s + mean_off_s)`` (floored at zero when the burst
    carries more than the whole budget).
    """
    burst_factor: float = 4.0
    mean_on_s: float = 2.0
    mean_off_s: float = 8.0

    def _phase_rates(self) -> Tuple[float, float]:
        if self.mean_on_s <= 0.0 or self.mean_off_s <= 0.0:
            raise ValueError("mean_on_s and mean_off_s must be positive; "
                             "for an unmodulated stream use PoissonProcess")
        duty = self.mean_on_s / (self.mean_on_s + self.mean_off_s)
        rate_on = self.burst_factor * self.rate
        rate_off = max(0.0, self.rate * (1.0 - self.burst_factor * duty)
                       / (1.0 - duty))
        return rate_on, rate_off

    def times(self, duration_s: float, rng: np.random.Generator) -> np.ndarray:
        """Alternate exponential ON/OFF holds (initial phase drawn from the
        stationary duty cycle) and pour Poisson arrivals into each hold at
        its phase rate."""
        if self.rate <= 0.0 or duration_s <= 0.0:
            return np.empty(0)
        rate_on, rate_off = self._phase_rates()
        duty = self.mean_on_s / (self.mean_on_s + self.mean_off_s)
        out = []
        # draw the initial phase from the stationary duty cycle so even
        # short windows offer ~rate on average
        t, on = 0.0, bool(rng.uniform() < duty)
        while t < duration_s:
            mean = self.mean_on_s if on else self.mean_off_s
            hold = float(rng.exponential(mean))
            r = rate_on if on else rate_off
            if r > 0.0 and hold > 0.0:
                n = int(rng.poisson(r * hold))
                if n:
                    out.append(t + np.sort(rng.uniform(0.0, hold, size=n)))
            t += hold
            on = not on
        if not out:
            return np.empty(0)
        ts = np.concatenate(out)
        return ts[ts < duration_s]


@dataclass(frozen=True)
class DiurnalProcess(ArrivalProcess):
    """Sinusoidal daily profile: rate(t) = rate * (1 + amp*sin(2πt/period)),
    floored at zero.

    Sampled by thinning against the peak rate (Lewis & Shedler), so the
    stream is an exact nonhomogeneous Poisson process; the profile wraps
    seamlessly across period boundaries for any ``duration_s``.  With
    ``amplitude`` <= 1 the trough rate is ``rate * (1 - amplitude)``;
    amplitudes above 1 are allowed and clip the around-trough rate to zero
    (a "dead of night" window with no arrivals at all).
    """
    amplitude: float = 0.6              # >= 0; > 1 clips the trough to zero
    period_s: float = 60.0              # compressed "day"

    def __post_init__(self) -> None:
        if self.amplitude < 0.0:
            raise ValueError("amplitude must be >= 0 (use phase, not sign)")
        if self.period_s <= 0.0:
            raise ValueError("period_s must be positive")

    def times(self, duration_s: float, rng: np.random.Generator) -> np.ndarray:
        if self.rate <= 0.0 or duration_s <= 0.0:
            return np.empty(0)
        lam_max = self.rate * (1.0 + self.amplitude)
        cand = PoissonProcess(lam_max).times(duration_s, rng)
        if cand.size == 0:
            return cand
        # rate floor: amplitudes > 1 would otherwise go negative at the
        # trough, which thinning would merely treat as 0 implicitly — make
        # the floor explicit so the profile is well-defined
        lam = np.maximum(0.0, self.rate * (1.0 + self.amplitude
                         * np.sin(2.0 * math.pi * cand / self.period_s)))
        keep = rng.uniform(0.0, 1.0, size=cand.size) < lam / lam_max
        return cand[keep]


@dataclass(frozen=True)
class TraceReplay(ArrivalProcess):
    """Replay recorded arrival times verbatim (rate is informational and
    defaults to 0.0 — replay has no free rate parameter).

    ``trace`` accepts any sequence of times (tuple, list, or a numpy
    vector straight from another process's :meth:`times` output) and is
    normalized to a tuple of Python floats at construction, so the replay
    round-trips another generator's stream without re-quantization: each
    ``numpy.float64`` converts to the bit-identical IEEE-754 double, and
    an engine run fed the replay reproduces the original run exactly
    (tested in ``tests/test_engine.py``).
    """
    rate: float = 0.0
    trace: Tuple[float, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "trace",
                           tuple(float(t) for t in self.trace))

    def times(self, duration_s: float, rng: np.random.Generator) -> np.ndarray:
        """Sort the recorded trace and clip it to the window; ``rng`` is
        unused (replay is deterministic)."""
        ts = np.sort(np.asarray(self.trace, dtype=np.float64))
        return ts[(ts >= 0.0) & (ts < duration_s)]

    def with_rate(self, rate: float) -> "ArrivalProcess":
        raise TypeError("TraceReplay cannot be rescaled to a target rate; "
                        "use a stochastic process for throughput search")


@dataclass(frozen=True)
class MergedArrivals(ArrivalProcess):
    """Deterministic multiplexer of independent component streams.

    Each component process (one per tenant) draws from its own child
    generator spawned off the handed ``rng`` (``Generator.spawn``), so

      * the merged stream is fully reproduced by one engine seed,
      * every component stream is statistically independent of the
        others, and
      * adding, removing or re-parameterizing one component never
        perturbs another component's draws (the children are indexed).

    :meth:`times_and_sources` is the engine-facing API: the merged sorted
    stream plus a parallel ``int32`` vector attributing each arrival to
    its component index (ties break toward the lower index — stable
    sort).  ``rate`` is derived (sum of component rates) unless given.
    """
    rate: float = -1.0
    processes: Tuple[ArrivalProcess, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "processes", tuple(self.processes))
        if not self.processes:
            raise ValueError("MergedArrivals needs at least one component "
                             "process")
        if self.rate < 0.0:
            object.__setattr__(
                self, "rate", float(sum(p.rate for p in self.processes)))

    def times_and_sources(self, duration_s: float, rng: np.random.Generator
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """The merged sorted arrival vector and the per-arrival component
        index, drawn from per-component child generators of ``rng``.

        A single-component merge passes ``rng`` straight through (there
        is nothing to interleave), so a one-tenant engine run consumes
        the arrival stream bit-identically to a classic single-tenant
        run — the golden-trace gate extends over the tenant layer.
        """
        if len(self.processes) == 1:
            ts = self.processes[0].times(duration_s, rng)
            return ts, np.zeros(ts.size, dtype=np.int32)
        rngs = rng.spawn(len(self.processes))
        parts = [p.times(duration_s, r)
                 for p, r in zip(self.processes, rngs)]
        times = np.concatenate(parts) if parts else np.empty(0)
        src = np.concatenate(
            [np.full(t.size, k, dtype=np.int32)
             for k, t in enumerate(parts)]) if parts else np.empty(0, np.int32)
        order = np.argsort(times, kind="stable")
        return times[order], src[order]

    def times(self, duration_s: float, rng: np.random.Generator) -> np.ndarray:
        return self.times_and_sources(duration_s, rng)[0]

    def with_rate(self, rate: float) -> "ArrivalProcess":
        """Rescale every component proportionally so the merged mean rate
        hits ``rate`` (fails for unscalable components like replay)."""
        if self.rate <= 0.0:
            raise TypeError("cannot rescale a zero-rate merged stream")
        f = rate / self.rate
        return MergedArrivals(
            rate=rate,
            processes=tuple(p.with_rate(p.rate * f) for p in self.processes))


ARRIVAL_KINDS: Dict[str, Type[ArrivalProcess]] = {
    "poisson": PoissonProcess,
    "bursty": BurstyOnOff,
    "diurnal": DiurnalProcess,
    "trace": TraceReplay,
    "merged": MergedArrivals,
}


def make_arrivals(kind: str, rate: float, **kw) -> ArrivalProcess:
    """Factory used by benchmarks/examples: ``make_arrivals("bursty", 100)``."""
    try:
        cls = ARRIVAL_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown arrival kind {kind!r}; "
                         f"choose from {sorted(ARRIVAL_KINDS)}") from None
    return cls(rate=rate, **kw)
