"""Table II — evaluated compute platforms.

Traditional platforms access storage over the network; near-storage (NS)
platforms sit behind a P2P PCIe link inside/next to the drive.  Numbers are
the paper's specs plus standard public figures (peak throughput, memory BW,
prices) where the paper doesn't list them.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Platform:
    name: str
    kind: str                  # cpu | gpu | fpga | dsa
    location: str              # remote (traditional) | near_storage
    peak_flops: float          # peak ops/s at deployment precision
                               # (int8 for FPGA/DSA systolic designs, per §VI)
    mem_bw: float              # B/s
    tdp_w: float
    idle_w: float
    freq_hz: float
    price_usd: float
    batch1_efficiency: float   # fraction of peak at batch size 1
    batch_saturation: int      # batch size at which efficiency ~ saturates
    pcie: str = "none"
    launch_s: float = 0.0      # per-GEMM kernel-launch / reconfigure cost
    sat_efficiency: float = 0.7  # efficiency at/beyond batch_saturation


# --- traditional (remote-storage) platforms --------------------------------
# 16 cores x 3 GHz x 2 AVX-512 FMA units (64 f32 FLOP/cyc)
XEON_8275CL = Platform(
    name="Baseline-CPU", kind="cpu", location="remote",
    peak_flops=3.0e12, mem_bw=131e9, tdp_w=240.0, idle_w=80.0,
    freq_hz=3.0e9, price_usd=8000.0, batch1_efficiency=0.30,
    batch_saturation=4, pcie="none", launch_s=2e-6, sat_efficiency=0.38)

RTX_2080TI = Platform(
    name="GPU", kind="gpu", location="remote",
    peak_flops=13.4e12, mem_bw=616e9, tdp_w=250.0, idle_w=55.0,
    freq_hz=1.35e9, price_usd=1200.0, batch1_efficiency=0.25,
    batch_saturation=64, pcie="gen3x16", launch_s=1.8e-5)

# 1024-PE DSA build at 250 MHz (Table II), int8
ALVEO_U280 = Platform(
    name="FPGA", kind="fpga", location="remote",
    peak_flops=2.05e12, mem_bw=460e9, tdp_w=225.0, idle_w=60.0,
    freq_hz=250e6, price_usd=7000.0, batch1_efficiency=0.5,
    batch_saturation=8, pcie="gen4x8", launch_s=2.5e-5)

# --- conventional near-storage platforms ------------------------------------
# quad A57, NEON fp16
NS_ARM_A57 = Platform(
    name="NS-ARM", kind="cpu", location="near_storage",
    peak_flops=0.10e12, mem_bw=25.6e9, tdp_w=15.0, idle_w=3.0,
    freq_hz=2.0e9, price_usd=500.0, batch1_efficiency=0.5,
    batch_saturation=2, pcie="gen3x4", launch_s=2e-6)

NS_JETSON_TX2 = Platform(
    name="NS-Mobile-GPU", kind="gpu", location="near_storage",
    peak_flops=1.33e12, mem_bw=59.7e9, tdp_w=15.0, idle_w=2.5,
    freq_hz=1.3e9, price_usd=400.0, batch1_efficiency=0.25,
    batch_saturation=16, pcie="gen3x4", launch_s=2.5e-5)

# 256-PE DSA build on the SmartSSD KU15P at 250 MHz (Table II), int8
NS_SMARTSSD_FPGA = Platform(
    name="NS-FPGA", kind="fpga", location="near_storage",
    peak_flops=0.9e12, mem_bw=19.2e9, tdp_w=18.0, idle_w=6.0,
    freq_hz=250e6, price_usd=1500.0, batch1_efficiency=0.7,
    batch_saturation=8, pcie="gen3x4", launch_s=1e-5)

# --- proposed: the DSA inside the CSD ----------------------------------------
# 128x128 PEs @1 GHz, 4 MB scratchpad, DDR5 — the DSE winner (Fig. 7);
# price is ASIC-Clouds-style amortized silicon + drive electronics (cost.py).
DSA_CSD = Platform(
    name="DSCS-Serverless", kind="dsa", location="near_storage",
    peak_flops=2 * 128 * 128 * 1e9, mem_bw=38e9, tdp_w=4.2, idle_w=0.6,
    freq_hz=1e9, price_usd=550.0, batch1_efficiency=0.75,
    batch_saturation=4, pcie="gen3x4")

PLATFORMS = {p.name: p for p in (
    XEON_8275CL, RTX_2080TI, ALVEO_U280,
    NS_ARM_A57, NS_JETSON_TX2, NS_SMARTSSD_FPGA, DSA_CSD)}

# canonical platform names for the two fleet roles the cluster engine and
# the autoscaling evaluation share (one definition, not scattered literals)
CPU_FALLBACK_PLATFORM = XEON_8275CL.name
DSCS_PLATFORM = DSA_CSD.name

PCIE_GBPS = {  # effective (post-overhead) unidirectional bandwidth
    "gen3x1": 0.85e9, "gen3x2": 1.7e9, "gen3x4": 3.4e9, "gen3x8": 6.8e9,
    "gen3x16": 13.6e9, "gen4x8": 13.6e9, "gen4x16": 27.2e9, "gen3x32": 27.2e9,
    "none": 3.4e9,
}
