"""Function scheduling, fallback and straggler mitigation (§V, §VI-C).

Event-driven simulator of the extended Kubernetes scheduler:
  * FCFS per node, run-to-completion, no multi-tenancy on a DSA
  * acceleratable functions are dispatched to the DSCS drive that HOLDS the
    request's data, if its DSA is free — otherwise fall back to the
    traditional CPU path (the drive still serves reads like a plain drive)
  * Prometheus-style telemetry drives the busy/available decision
  * hedged dispatch: if a request sits past a latency budget, re-issue on
    the fallback path and take the earlier finisher (tail/straggler
    mitigation — our addition, evaluated in fig16)
"""
from __future__ import annotations

import heapq
import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.function import Pipeline
from repro.core.latency import LatencyModel
from repro.core.placement import StoragePool
from repro.core.platforms import PLATFORMS, Platform


@dataclass
class Telemetry:
    """Prometheus-analogue counters."""
    counters: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    def inc(self, name: str, v: float = 1.0) -> None:
        self.counters[name] += v

    def get(self, name: str) -> float:
        return self.counters[name]


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: dict = field(compare=False, default_factory=dict)


@dataclass
class RequestResult:
    arrival: float
    finish: float
    accelerated: bool
    hedged: bool = False

    @property
    def latency(self) -> float:
        return self.finish - self.arrival


class ClusterSim:
    """Simulates a fleet: N DSCS drives + M CPU fallback nodes serving a
    Poisson request stream of Table I pipelines."""

    def __init__(self, *, n_dscs: int = 100, n_cpu: int = 100,
                 latency_model: Optional[LatencyModel] = None,
                 hedge_budget_s: Optional[float] = None, seed: int = 0):
        self.lm = latency_model or LatencyModel(seed=seed)
        self.pool = StoragePool(n_plain=64, n_dscs=n_dscs)
        self.n_dscs = n_dscs
        self.n_cpu = n_cpu
        self.hedge_budget_s = hedge_budget_s
        self.rng = np.random.default_rng(seed)
        self.telemetry = Telemetry()

    # -- service-time draws ----------------------------------------------
    def _service(self, pipe: Pipeline, plat: Platform) -> float:
        return self.lm.e2e(plat, pipe.workload, q=None)

    def run(self, pipelines: List[Pipeline], *, rps: float,
            duration_s: float = 120.0) -> List[RequestResult]:
        """Poisson arrivals of randomly-sampled pipelines; FCFS queues."""
        dsa_free = [0.0] * self.n_dscs      # next-free time per DSA drive
        cpu_free = [0.0] * self.n_cpu
        results: List[RequestResult] = []
        t = 0.0
        seq = 0
        while t < duration_s:
            t += float(self.rng.exponential(1.0 / rps))
            pipe = pipelines[int(self.rng.integers(len(pipelines)))]
            seq += 1
            accel = all(f.acceleratable for f in pipe.functions[:2])
            if accel:
                # data-locality: the request's payload lives on one DSCS
                # drive; dispatch there if free "enough", else fall back
                d = int(self.rng.integers(self.n_dscs))
                start = max(t, dsa_free[d])
                queue_wait = start - t
                if queue_wait <= (self.hedge_budget_s or math.inf):
                    svc = self._service(pipe, PLATFORMS["DSCS-Serverless"])
                    dsa_free[d] = start + svc
                    results.append(RequestResult(t, start + svc, True))
                    self.telemetry.inc("dscs_dispatch")
                    continue
                self.telemetry.inc("dscs_fallback")
            # traditional path: least-loaded CPU node
            c = int(np.argmin(cpu_free))
            start = max(t, cpu_free[c])
            svc = self._service(pipe, PLATFORMS["Baseline-CPU"])
            cpu_free[c] = start + svc
            results.append(RequestResult(t, start + svc, False,
                                         hedged=accel))
            self.telemetry.inc("cpu_dispatch")
        return results

    # -- throughput under SLA (Fig. 12 methodology) ------------------------
    def max_throughput(self, pipelines: List[Pipeline], *, sla_s: float,
                       sla_frac: float = 0.99, duration_s: float = 60.0,
                       lo: float = 1.0, hi: float = 4096.0) -> float:
        """Binary-search the highest Poisson RPS meeting the SLA."""
        def ok(rps: float) -> bool:
            res = self.run(pipelines, rps=rps, duration_s=duration_s)
            if not res:
                return True
            lat = np.array([r.latency for r in res])
            return float(np.mean(lat <= sla_s)) >= sla_frac
        for _ in range(12):
            mid = math.sqrt(lo * hi)
            if ok(mid):
                lo = mid
            else:
                hi = mid
        return lo
