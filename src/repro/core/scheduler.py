"""Function scheduling, fallback and straggler mitigation (§V, §VI-C).

Thin façade over the discrete-event engine in :mod:`repro.core.engine`.
``ClusterSim`` keeps the public surface the figures, examples and tests
have always used (``run``, ``max_throughput``, ``telemetry``,
``RequestResult``) while the actual fleet dynamics — per-drive FCFS
queues, data-aware placement through :class:`StoragePool`, hedged dispatch
racing the DSCS and CPU paths, and pluggable arrival processes — live in
the engine's event loop:

  * FCFS per node, run-to-completion, no multi-tenancy on a DSA
  * acceleratable functions are dispatched to the DSCS drive that HOLDS the
    request's data (deterministic placement hash), never a random draw
  * Prometheus-style telemetry drives the busy/available decision
  * hedged dispatch: if a request is still queued past ``hedge_budget_s``,
    a second copy is issued on the least-loaded CPU node, both copies race,
    the earlier finisher wins and the loser is cancelled (tail/straggler
    mitigation — our addition, evaluated in fig16)
  * autoscaling: ``run_autoscaled`` attaches an
    :class:`~repro.core.autoscale.AutoscalePolicy` control loop that
    resizes the active fleet at epoch boundaries and scores the run on
    cost per SLA-met request and energy per request (fig20); the policy
    classes are re-exported here as the public API
  * multi-tenancy: ``run_tenants`` serves several
    :class:`~repro.core.tenancy.TenantSpec` streams through one fleet
    under a pluggable drive scheduler (FCFS run-to-completion baseline,
    weighted time-slicing, spatial DSA-lane partitioning) and returns
    per-tenant :class:`~repro.core.tenancy.TenantReport` scorecards
    (fig21 fairness study); the tenancy API is re-exported here
  * fault injection: ``ClusterSim(faults=FaultPlan(...))`` attaches the
    seeded failure/recovery layer from :mod:`repro.core.faults` — drive
    fail-stop and gray-failure stalls, CPU node crashes, retry with
    backoff under a budget, replica repair, timeout-based failure
    detection — scored by ``fault_stats()`` and studied in fig23; the
    fault API is re-exported here
  * overload control: ``ClusterSim(overload=OverloadControl(...))``
    attaches the deterministic admission / load-shedding / backpressure /
    brownout layer from :mod:`repro.core.overload` that keeps goodput
    near capacity past the saturation knee instead of collapsing into a
    retry storm — scored by ``overload_stats()`` and studied in fig24;
    the overload API is re-exported here

Every run is reproducible from the constructor seed: repeated ``run``
calls on one ``ClusterSim`` (and two sims built with equal seeds) produce
identical ``RequestResult`` streams.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.arrivals import ArrivalProcess, PoissonProcess
from repro.core.autoscale import (AutoscaleAction,  # noqa: F401
                                  AutoscalePolicy, AutoscaleReport,
                                  EWMAPolicy, ReactivePolicy, StaticPolicy,
                                  WorstTenantPolicy, evaluate_policy)
from repro.core.engine import (ClusterEngine, EngineTrace,  # noqa: F401
                               FleetSnapshot, RequestResult, Telemetry)
from repro.core.faults import (CpuCrash, DriveFailure,  # noqa: F401
                               DriveStall, ExponentialBackoff, FaultPlan,
                               FixedRetry, NoRetry, RepairModel,
                               RetryBudget, RetryPolicy)
from repro.core.function import Pipeline
from repro.core.latency import LatencyModel
from repro.core.overload import (AdmitAll, Backpressure,  # noqa: F401
                                 Brownout, OverloadControl, QueueThreshold,
                                 ShedPolicy, ThrottledArrivals, TokenBucket)
from repro.core.placement import StoragePool
from repro.core.tenancy import (DriveScheduler,  # noqa: F401
                                FCFSRunToCompletion, SpatialPartition,
                                TenantReport, TenantSpec, WeightedTimeSlice,
                                jain_index, tenant_reports)
from repro.core.sharding import (MailboxOverflow, ShardMailbox,  # noqa: F401
                                 ShardPlan)
from repro.core.tiering import (DriveCache, MigrationPolicy,  # noqa: F401
                                TierConfig)

__all__ = ["AdmitAll", "AutoscaleAction", "AutoscalePolicy",
           "AutoscaleReport", "Backpressure", "Brownout", "ClusterSim",
           "CpuCrash", "DriveCache", "DriveFailure", "DriveScheduler",
           "DriveStall", "EWMAPolicy", "ExponentialBackoff",
           "FCFSRunToCompletion", "FaultPlan", "FixedRetry",
           "FleetSnapshot", "MailboxOverflow", "MigrationPolicy",
           "NoRetry", "OverloadControl", "QueueThreshold",
           "ReactivePolicy", "RepairModel", "RequestResult",
           "RetryBudget", "RetryPolicy", "ShardMailbox", "ShardPlan",
           "ShedPolicy", "SpatialPartition", "StaticPolicy", "Telemetry",
           "TenantReport", "TenantSpec", "ThrottledArrivals",
           "TierConfig", "TokenBucket", "WeightedTimeSlice",
           "WorstTenantPolicy", "jain_index", "tenant_reports"]


class ClusterSim:
    """Simulates a fleet: N DSCS drives + M CPU fallback nodes serving a
    request stream of Table I pipelines (Poisson by default; any
    :class:`ArrivalProcess` via ``arrivals=``)."""

    def __init__(self, *, n_dscs: int = 100, n_cpu: int = 100,
                 latency_model: Optional[LatencyModel] = None,
                 hedge_budget_s: Optional[float] = None, seed: int = 0,
                 tier: Optional[TierConfig] = None,
                 faults: Optional[FaultPlan] = None,
                 overload: Optional[OverloadControl] = None):
        self.lm = latency_model or LatencyModel(seed=seed)
        self.pool = StoragePool(n_plain=64, n_dscs=n_dscs)
        self.n_dscs = n_dscs
        self.n_cpu = n_cpu
        self.hedge_budget_s = hedge_budget_s
        self.seed = seed
        self.tier = tier
        self.faults = faults
        self.overload = overload
        self.telemetry = Telemetry()
        self.engine = ClusterEngine(
            n_dscs=n_dscs, n_cpu=n_cpu, latency_model=self.lm,
            hedge_budget_s=hedge_budget_s, seed=seed,
            telemetry=self.telemetry, tier=tier, faults=faults,
            overload=overload)

    def run(self, pipelines: List[Pipeline], *, rps: Optional[float] = None,
            duration_s: float = 120.0,
            arrivals: Optional[ArrivalProcess] = None,
            timeout_s: Optional[float] = None) -> List[RequestResult]:
        """Simulate ``duration_s`` of offered load.

        Pass either ``rps`` (Poisson arrivals at that rate — the historical
        interface) or an explicit ``arrivals`` process.  ``timeout_s``
        enforces a per-request deadline: a request still unfinished that
        long after arrival is abandoned (``finish`` NaN, ``winner`` "").
        """
        if arrivals is None:
            if rps is None:
                raise ValueError("pass rps= or arrivals=")
            arrivals = PoissonProcess(rate=rps)
        elif rps is not None:
            raise ValueError("pass either rps= or arrivals=, not both "
                             "(rps would be silently ignored)")
        return self.engine.run(pipelines, arrivals=arrivals,
                               duration_s=duration_s, timeout_s=timeout_s)

    def run_sharded(self, pipelines: List[Pipeline], *,
                    rps: Optional[float] = None, duration_s: float = 120.0,
                    arrivals: Optional[ArrivalProcess] = None,
                    n_shards: int = 1, processes: Optional[int] = None,
                    timeout_s: Optional[float] = None,
                    backend: str = "segmented") -> EngineTrace:
        """Simulate the same offered load sharded by drive partition.

        ``n_shards=1`` is the classic event loop (identical to ``run``,
        but returning the raw :class:`EngineTrace` arrays instead of
        materialized :class:`RequestResult` objects — the natural form
        at the fleet scales sharding targets).  With ``n_shards >= 2``
        the fleet splits into disjoint drive partitions executed by
        :mod:`repro.core.sharding`; see
        :meth:`ClusterEngine.run_sharded`.  ``queue_stats``,
        ``power_stats``, ``fault_stats`` and ``tier_stats`` all report
        the merged fleet view afterwards.  ``backend`` selects the fast
        path's Lindley solver (:mod:`repro.core.lindley`).
        """
        if arrivals is None:
            if rps is None:
                raise ValueError("pass rps= or arrivals=")
            arrivals = PoissonProcess(rate=rps)
        elif rps is not None:
            raise ValueError("pass either rps= or arrivals=, not both "
                             "(rps would be silently ignored)")
        return self.engine.run_sharded(pipelines, arrivals=arrivals,
                                       duration_s=duration_s,
                                       n_shards=n_shards,
                                       processes=processes,
                                       timeout_s=timeout_s,
                                       backend=backend)

    def queue_stats(self):
        """Queue-depth telemetry from the most recent ``run``."""
        return self.engine.queue_stats()

    def fault_stats(self):
        """Fault-injection & recovery telemetry from the most recent run
        (``None`` when the sim was built without a
        :class:`~repro.core.faults.FaultPlan` and the run set no
        ``timeout_s``)."""
        return self.engine.fault_stats()

    def tier_stats(self):
        """Tiered data-layer telemetry from the most recent run (``None``
        when the sim was built without an enabled
        :class:`~repro.core.tiering.TierConfig`)."""
        return self.engine.tier_stats()

    def overload_stats(self):
        """Overload-control telemetry from the most recent run (``None``
        when the sim was built without an enabled
        :class:`~repro.core.overload.OverloadControl`): admitted /
        rejected / shed counts split by cause, class and tenant, the
        pushback timeline, brownout intervals and goodput."""
        return self.engine.overload_stats()

    # -- multi-tenancy (ROADMAP item; see repro.core.tenancy) ----------------
    def run_tenants(self, tenants: Sequence[TenantSpec], *,
                    duration_s: float,
                    scheduler: Optional[DriveScheduler] = None,
                    controller: Optional[AutoscalePolicy] = None,
                    ) -> Tuple[EngineTrace, List[TenantReport]]:
        """Serve several tenants' streams through this fleet and score
        each tenant.

        Every :class:`~repro.core.tenancy.TenantSpec` brings its own
        pipeline mix, arrival process, SLA target and share weight; the
        streams are multiplexed deterministically from the sim seed.
        ``scheduler`` picks how drives share their DSA —
        :class:`FCFSRunToCompletion` (default, the paper's §V baseline),
        :class:`WeightedTimeSlice` or :class:`SpatialPartition`.
        ``controller`` optionally attaches an autoscaling policy (FCFS
        scheduler only).  Returns the raw
        :class:`~repro.core.engine.EngineTrace` (``trace.tenant`` maps
        each request to its tenant) and one
        :class:`~repro.core.tenancy.TenantReport` per tenant; the
        engine's :meth:`~repro.core.engine.ClusterEngine.tenant_stats`
        holds the per-tenant queue/busy-seconds telemetry afterwards.
        """
        trace = self.engine.run_soa(tenants=tenants, duration_s=duration_s,
                                    scheduler=scheduler,
                                    controller=controller)
        return trace, tenant_reports(trace, tenants,
                                     self.engine.tenant_stats())

    def tenant_stats(self):
        """Per-tenant telemetry from the most recent ``run_tenants``."""
        return self.engine.tenant_stats()

    # -- autoscaling (ROADMAP item; see repro.core.autoscale) ----------------
    def run_autoscaled(self, pipelines: List[Pipeline], *,
                       policy: AutoscalePolicy, arrivals: ArrivalProcess,
                       duration_s: float, sla_s: float = 0.6,
                       dscs_wake_s: float = 0.2) -> AutoscaleReport:
        """Run ``duration_s`` of offered load with ``policy`` resizing the
        fleet at its epoch boundaries, and score the run on cost per
        SLA-met request and energy per request.

        The sim's ``n_dscs``/``n_cpu`` become the provisioned maxima the
        policy scales within; the run uses a fresh engine with this sim's
        seed/latency model, so it neither consumes nor disturbs the sim's
        own telemetry, and repeated calls are exactly reproducible.
        """
        return evaluate_policy(
            policy, pipelines, arrivals=arrivals, duration_s=duration_s,
            n_dscs=self.n_dscs, n_cpu=self.n_cpu, sla_s=sla_s,
            hedge_budget_s=self.hedge_budget_s, seed=self.seed,
            latency_model=self.lm, dscs_wake_s=dscs_wake_s)

    # -- throughput under SLA (Fig. 12 methodology) ------------------------
    def max_throughput(self, pipelines: List[Pipeline], *, sla_s: float,
                       sla_frac: float = 0.99, duration_s: float = 60.0,
                       lo: float = 1.0, hi: float = 4096.0,
                       arrivals: Optional[ArrivalProcess] = None) -> float:
        """Binary-search the highest mean RPS meeting the SLA.  ``arrivals``
        selects the load *shape*; its rate is rescaled at every probe (so
        trace replay, which has no free rate, is rejected).

        Every probe replays the same :class:`~repro.core.engine.SampleBank`
        (common random numbers): pipeline picks and service-tail draws are
        sampled once for the whole search, and for Poisson load the arrival
        stream itself is one cached vector of unit-rate exponential gaps
        rescaled per probe (``t_i(r) = cumsum(gaps)_i / r``) — a single
        sampling pass instead of twelve, and probes differ only through
        the offered rate, not sampling noise.  Shaped (bursty/diurnal)
        processes keep their wall-clock phase structure, so only their
        arrival stream is redrawn per probe; picks and service draws stay
        banked.
        """
        proto = arrivals if arrivals is not None else PoissonProcess(rate=1.0)
        bank = self.engine.sample_bank(pipelines)
        poisson = type(proto) is PoissonProcess
        if poisson:
            # one cached unit-rate arrival stream for the whole search
            gap_rng = np.random.default_rng(
                np.random.SeedSequence(self.seed).spawn(2)[0])
            cum = np.cumsum(gap_rng.standard_exponential(
                max(int(hi * duration_s * 1.25), 64)))

        def probe(rps: float) -> EngineTrace:
            nonlocal cum
            if not poisson:
                return self.engine.run_soa(pipelines, duration_s=duration_s,
                                           arrivals=proto.with_rate(rps),
                                           bank=bank)
            horizon = rps * duration_s
            while cum[-1] < horizon:    # rare: extend the cached stream
                cum = np.concatenate([cum, cum[-1] + np.cumsum(
                    gap_rng.standard_exponential(cum.size))])
            times = cum[:np.searchsorted(cum, horizon)] / rps
            return self.engine.run_soa(pipelines, times=times, bank=bank)

        def ok(rps: float) -> bool:
            trace = probe(rps)
            if not trace.n:
                return True
            return float(np.mean(trace.latency <= sla_s)) >= sla_frac

        for _ in range(12):
            mid = math.sqrt(lo * hi)
            if ok(mid):
                lo = mid
            else:
                hi = mid
        return lo
