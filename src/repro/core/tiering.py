"""Tiered data layer (ROADMAP item): per-drive DRAM caches, k-way
replication, a remote backing object store, and hot-key migration.

The paper's placement story (§V) pins one static SHA-1 replica per object,
so Zipf-hot keys melt a single drive.  This module models the storage
hierarchy that fixes it, and :class:`~repro.core.engine.ClusterEngine`
interprets it on the SoA hot path (``ClusterEngine(tier=TierConfig(...))``):

  * **per-drive DRAM cache** — :class:`DriveCache`: LRU eviction plus a
    TinyLFU-style frequency-admission filter (``admit_after`` accesses
    before an object may displace residents).  A hit serves the payload
    from drive DRAM instead of flash: the engine subtracts
    ``LatencyModel.cache_hit_savings`` from that copy's service time.
  * **k-way replication** — every object maps to ``replication_k``
    distinct drives by rendezvous hashing (:func:`build_replica_table`,
    the same scheme as ``StoragePool.replicas``).  The engine routes each
    arrival to the cache-warmest, least-loaded replica.
  * **remote backing object store** — replicas materialize lazily: the
    first access on a secondary (or migrated-to) drive pays
    ``LatencyModel.backing_fetch`` to pull the object from the backing
    tier; afterwards the copy is drive-local.
  * **hot-key migration** — :class:`MigrationController` watches the
    engine's per-drive queue telemetry at epoch boundaries (the same hook
    cadence the autoscale control loop uses) and retargets the hottest
    keys of saturated drives onto the coldest drives; the durable copy
    follows via a backing-store fetch on first access.

With the tier disabled (``replication_k == 1``, ``cache_bytes == 0``,
per-request unique objects, no migration) the engine never enters any of
these paths and its event stream stays bit-identical to the golden traces.

The tier interfaces follow the Mooncake-style store connectors (hit-rate
and transfer telemetry per tier); the Zipf-skewed popularity study lives
in ``benchmarks/figures.py::fig22_tiered_storage``.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "DriveCache", "MigrationController", "MigrationPolicy", "TierConfig",
    "build_replica_table", "zipf_object_ids",
]


@dataclass(frozen=True)
class MigrationPolicy:
    """Knobs of the epoch-driven hot-key rebalancer.

    Every ``epoch_s`` simulated seconds the controller compares live
    per-drive backlogs; when the hottest drive's queue exceeds the
    coldest's by at least ``min_queue_imbalance`` copies, up to
    ``max_moves_per_epoch`` of its most-accessed keys are retargeted onto
    the coldest drives.
    """
    epoch_s: float = 1.0
    max_moves_per_epoch: int = 4
    min_queue_imbalance: int = 4

    def validate(self) -> None:
        if self.epoch_s <= 0.0:
            raise ValueError("migration epoch_s must be positive")
        if self.max_moves_per_epoch < 1:
            raise ValueError("max_moves_per_epoch must be >= 1")
        if self.min_queue_imbalance < 1:
            raise ValueError("min_queue_imbalance must be >= 1")


@dataclass(frozen=True)
class TierConfig:
    """The storage-hierarchy configuration one engine run interprets.

    ``replication_k`` durable replicas per object; ``cache_bytes`` of
    DRAM cache per drive (0 disables caching); ``admit_after`` accesses
    before the frequency filter admits an object (1 = plain LRU,
    always-admit); ``n_objects`` distinct objects with Zipf(``zipf_s``)
    popularity (0 keeps the classic one-unique-object-per-request model);
    ``object_bytes`` overrides the per-pipeline request payload size
    (0 = use each pipeline's ``workload.request_bytes``); ``migration``
    attaches the hot-key rebalancer.

    The default config is **disabled**: it models exactly the paper's
    static single-replica placement and the engine takes the classic
    bit-exact path.
    """
    replication_k: int = 1
    cache_bytes: int = 0
    admit_after: int = 1
    n_objects: int = 0
    zipf_s: float = 1.1
    object_bytes: int = 0
    migration: Optional[MigrationPolicy] = None

    @property
    def enabled(self) -> bool:
        """True when any tier mechanism deviates from the paper's static
        single-replica placement."""
        return (self.replication_k > 1 or self.cache_bytes > 0
                or self.n_objects > 0 or self.migration is not None)

    def validate(self) -> None:
        if self.replication_k < 1:
            raise ValueError("replication_k must be >= 1")
        if self.cache_bytes < 0:
            raise ValueError("cache_bytes must be >= 0")
        if self.admit_after < 1:
            raise ValueError("admit_after must be >= 1")
        if self.n_objects < 0:
            raise ValueError("n_objects must be >= 0")
        if self.n_objects and self.zipf_s < 0.0:
            raise ValueError("zipf_s must be >= 0")
        if self.object_bytes < 0:
            raise ValueError("object_bytes must be >= 0")
        if self.migration is not None:
            self.migration.validate()


class DriveCache:
    """One drive's DRAM object cache: LRU eviction behind a TinyLFU-style
    frequency-admission filter.

    ``access(key, size)`` is the read path: a resident key is a **hit**
    (refreshed to MRU); a miss bumps the key's frequency counter and
    admits it once it has been seen ``admit_after`` times, evicting LRU
    residents to make room.  ``warm(key)`` peeks without mutating any
    state — what the replica router consults.  Objects larger than the
    whole cache are never admitted.
    """

    __slots__ = ("capacity_bytes", "admit_after", "used_bytes", "_res",
                 "_freq", "hits", "misses", "evictions", "admitted",
                 "rejected")

    def __init__(self, capacity_bytes: int, admit_after: int = 1):
        if capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0")
        if admit_after < 1:
            raise ValueError("admit_after must be >= 1")
        self.capacity_bytes = capacity_bytes
        self.admit_after = admit_after
        self.used_bytes = 0
        self._res: "OrderedDict[int, int]" = OrderedDict()  # key -> size
        self._freq: Dict[int, int] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.admitted = 0
        self.rejected = 0

    def __contains__(self, key) -> bool:
        return key in self._res

    def warm(self, key) -> bool:
        """Resident check without touching LRU order or frequencies."""
        return key in self._res

    def access(self, key, size: int) -> bool:
        """One read of ``key`` (``size`` bytes); returns True on a hit."""
        res = self._res
        if key in res:
            res.move_to_end(key)
            self.hits += 1
            return True
        self.misses += 1
        f = self._freq.get(key, 0) + 1
        self._freq[key] = f
        if f < self.admit_after or size > self.capacity_bytes:
            self.rejected += 1
            return False
        while self.used_bytes + size > self.capacity_bytes:
            _, ev_size = res.popitem(last=False)
            self.used_bytes -= ev_size
            self.evictions += 1
        res[key] = size
        self.used_bytes += size
        self.admitted += 1
        return False

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
                "evictions": self.evictions, "admitted": self.admitted,
                "rejected": self.rejected, "used_bytes": self.used_bytes,
                "resident": len(self._res)}


def zipf_object_ids(n: int, n_objects: int, s: float,
                    rng: np.random.Generator) -> np.ndarray:
    """``n`` object ids drawn i.i.d. from a Zipf(``s``) popularity law
    over ``n_objects`` objects (object 0 is the hottest).  Sampled by
    inverse-CDF over the normalized rank weights, so the draw stream is
    exactly reproducible from ``rng``."""
    if n_objects < 1:
        raise ValueError("n_objects must be >= 1")
    ranks = np.arange(1, n_objects + 1, dtype=np.float64)
    w = ranks ** -s
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    u = rng.uniform(size=n)
    return np.searchsorted(cdf, u, side="left").astype(np.int64)


def _hrw_ranking(key: str, n_drives: int) -> List[int]:
    """Drive indices ordered by rendezvous-hash score for ``key`` — the
    same ``SHA1(f"{key}|{j}")`` scheme as ``StoragePool.replicas``."""
    sha1 = hashlib.sha1
    return sorted(range(n_drives),
                  key=lambda j: int(sha1(
                      f"{key}|{j}".encode()).hexdigest(), 16),
                  reverse=True)


def build_replica_table(n_objects: int, n_drives: int,
                        k: int) -> List[List[int]]:
    """Per-object replica drive lists: object ``o`` (key ``obj-{o}``)
    lives on the top-``k`` drives of its rendezvous ranking, primary
    first.  Mutable on purpose — the migration controller retargets
    entries in place."""
    if n_drives < 1:
        raise ValueError("need at least one drive")
    k = min(max(1, k), n_drives)
    return [_hrw_ranking(f"obj-{o}", n_drives)[:k] for o in range(n_objects)]


@dataclass
class MigrationController:
    """Epoch-driven hot-key rebalancer over the engine's live telemetry.

    The engine feeds it per-drive live queue depths and the per-drive
    object access counts of the closing epoch; :meth:`plan` returns the
    ``(object, from_drive, to_drive)`` moves to apply to the replica
    table.  Moves only retarget *routing* — the durable copy materializes
    on the target via a backing-store fetch on first access, exactly like
    a lazy replica.
    """
    policy: MigrationPolicy = field(default_factory=MigrationPolicy)
    moves: int = 0                      # total keys migrated (telemetry)
    epochs: int = 0                     # epochs evaluated
    log: List[Tuple[float, int, int, int]] = field(default_factory=list)

    def plan(self, t: float, queue_depth: List[int], busy: List[int],
             access: List[Dict[int, int]],
             replicas: List[List[int]]) -> List[Tuple[int, int, int]]:
        """One epoch's decision: hottest keys off the most-backlogged
        drive onto the least-loaded drives.  Deterministic — ties break
        toward lower drive/object ids."""
        self.epochs += 1
        nd = len(queue_depth)
        if nd < 2:
            return []
        load = [queue_depth[d] + busy[d] for d in range(nd)]
        hot = max(range(nd), key=lambda d: (load[d], -d))
        cold_order = sorted(range(nd), key=lambda d: (load[d], d))
        coldest = cold_order[0]
        if load[hot] - load[coldest] < self.policy.min_queue_imbalance:
            return []
        # hottest keys on the hot drive this epoch, most-accessed first
        hot_keys = sorted(access[hot].items(), key=lambda kv: (-kv[1], kv[0]))
        out: List[Tuple[int, int, int]] = []
        for o, _cnt in hot_keys:
            if len(out) >= self.policy.max_moves_per_epoch:
                break
            reps = replicas[o]
            if hot not in reps:
                continue                # routing already moved elsewhere
            tgt = next((d for d in cold_order
                        if d != hot and d not in reps), None)
            if tgt is None:
                continue                # already replicated everywhere
            out.append((o, hot, tgt))
        for o, frm, to in out:
            self.log.append((t, o, frm, to))
        self.moves += len(out)
        return out


# --------------------------------------------------------------------------
# shard-local bookkeeping merge (sharded runs)
# --------------------------------------------------------------------------

def merge_tier_stats(states: List[Optional[dict]]) -> Optional[dict]:
    """Merge per-shard ``tier_stats()`` dicts into one fleet view.

    Sharded runs build replica tables and caches *shard-local* (each
    shard replicates its objects across its own drives only, so tier
    routing never crosses a shard boundary); this folds the books back
    into the single-engine schema: hit/miss/eviction counters and
    backing-store traffic sum, per-drive cache stats concatenate in
    shard (= drive) order, object counts add across the shard-local
    tables, and migration logs concatenate with moves summed.  Returns
    ``None`` when tiering was off.
    """
    live = [s for s in states if s is not None]
    if not live:
        return None
    hits = sum(s["cache"]["hits"] for s in live)
    misses = sum(s["cache"]["misses"] for s in live)
    per_drive: List[dict] = []
    for s in live:
        per_drive += s["cache"]["per_drive"]
    migs = [s["migration"] for s in live if s["migration"] is not None]
    return {
        "replication_k": live[0]["replication_k"],
        "n_objects": sum(s["n_objects"] for s in live),
        "cache_bytes": live[0]["cache_bytes"],
        "cache": {
            "hits": hits, "misses": misses,
            "hit_rate": hits / (hits + misses) if hits + misses else 0.0,
            "evictions": sum(s["cache"]["evictions"] for s in live),
            "per_drive": per_drive,
        },
        "backing_fetches": sum(s["backing_fetches"] for s in live),
        "backing_s": sum(s["backing_s"] for s in live),
        "migration": (None if not migs else
                      {"moves": sum(m["moves"] for m in migs),
                       "epochs": max(m["epochs"] for m in migs),
                       "log": [e for m in migs for e in m["log"]]}),
    }


__all__.append("merge_tier_stats")
