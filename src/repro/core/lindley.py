"""Segmented-scan Lindley solver: every server's FCFS queue in one pass.

The partitioned fast path (:mod:`repro.core.sharding`) solves per-server
FCFS queues with the Lindley recurrence.  For rows sorted by server key
with per-segment arrivals ``t`` and service demands ``s``, the service
start obeys the segment-reset scan identity::

    start_j = max(t_j,  max_{i <= j, same segment} (t_i - P_i)  +  P_j)

where ``P_j = sum(s_a .. s_{j-1})`` is the within-segment exclusive
prefix of the service demands — a cumulative sum plus a running maximum,
both resetting at segment boundaries.  Until this module, the engine
evaluated that identity through one zero-padded dense ``(n_servers,
longest_queue)`` array: under a skewed key distribution (one hot server
holding most of the stream) ``longest_queue -> n`` and the pad blows up
to ``O(n_servers * n)`` memory — the exact regime (Zipf object
popularity, hot drives) where the simulator must be fastest.

Two backends evaluate the identity over the contiguous flat layout:

``segmented`` (default, numpy)
    Segments are grouped into power-of-two **length buckets** (segment
    length in ``(2^{b-1}, 2^b]`` lands in bucket ``b``), each bucket
    solved as a dense ``(rows_in_bucket, 2^b)`` block.  A bucket's pad
    is < 2x its real rows, so peak scratch is ``O(n)`` no matter how
    skewed the keys are, and the per-bucket math is the *identical*
    sequence of IEEE-754 operations the old padded-dense layout ran
    (row-wise ``cumsum`` / ``maximum.accumulate``) — outputs are
    byte-for-byte the same, which is what lets the differential
    shard-equivalence harness and the golden traces extend over the new
    backend unchanged.  A flat global-cumsum formulation was rejected:
    re-associating the prefix sums changes the low-order float bits and
    would have broken the bit-identity gate.

``pallas``
    The same bucketed recurrence as a grid-blocked Pallas TPU kernel
    (:mod:`repro.kernels.lindley`): rows ride the lane dimension, the
    depth axis is scanned sequentially with a grid-carried fp64 VMEM
    ``(cumsum, running-max)`` state — float64 via jax's x64 mode,
    ``interpret=True`` off-TPU like every other kernel in the repo.
    Because the kernel performs the same fp64 operations in the same
    order, its output is bit-identical to the numpy backend (pinned in
    ``tests/test_kernels.py``).

``dense``
    The legacy zero-padded ``(n_servers, longest_queue)`` layout, kept
    as the perf baseline ``benchmarks/bench_engine.py`` measures the
    skew speedup against.

Scratch buffers are pooled per process (:data:`_POOL`) and reused across
buckets, shards, and the accel/non-accel solve phases, so a long run
allocates its working set once.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

__all__ = ["BACKENDS", "queue_depth_max", "segment_fenceposts",
           "solve_segments"]

BACKENDS = ("segmented", "pallas", "dense")

# Reusable scratch: name -> grow-only 1D float64 buffer.  Forked shard
# workers each inherit (copy-on-write) and then own their pool, so the
# drive phase and the CPU phase of one worker share one working set.
_POOL: Dict[str, np.ndarray] = {}


def _scratch(name: str, size: int) -> np.ndarray:
    buf = _POOL.get(name)
    if buf is None or buf.size < size:
        buf = np.empty(max(size, 1), dtype=np.float64)
        _POOL[name] = buf
    return buf


def segment_fenceposts(keys: np.ndarray, lo: int, hi: int) -> np.ndarray:
    """``n_servers + 1`` fenceposts into ``keys`` (sorted server ids in
    ``[lo, hi)``): server ``j``'s rows are ``[seg[j], seg[j+1])``."""
    return np.searchsorted(keys, np.arange(lo, hi + 1))


def _solve_dense(seg: np.ndarray, t: np.ndarray, s: np.ndarray,
                 start: np.ndarray) -> None:
    """Legacy padded-dense evaluation: one ``(n_servers, longest)``
    zero-padded block (pads sit after each row's data, so the row-wise
    prefix scans never see them)."""
    lens = np.diff(seg)
    nserv = lens.size
    rows = np.repeat(np.arange(nserv), lens)
    pos = np.arange(t.size) - np.repeat(seg[:-1], lens)
    shape = (nserv, int(lens.max()))
    T = np.zeros(shape)
    S = np.zeros(shape)
    T[rows, pos] = t
    S[rows, pos] = s
    C = np.cumsum(S, axis=1)
    prev = C - S
    M = np.maximum.accumulate(T - prev, axis=1)
    start[:] = np.maximum(T, M + prev)[rows, pos]


def _bucket_rows(lens: np.ndarray):
    """Group nonempty segments into power-of-two length buckets.

    Returns ``(order, bounds, widths)``: ``order`` lists segment indices
    sorted by bucket, ``bounds`` are fenceposts into ``order`` per
    bucket, ``widths[b]`` is the bucket's padded row width (< 2x the
    shortest member, so bucket scratch is < 2x its real row count).
    """
    ne = np.flatnonzero(lens)
    if not ne.size:
        z = np.zeros(0, dtype=np.int64)
        return z, np.zeros(1, dtype=np.int64), z
    # bucket id = ceil(log2(len)): len in (2^{b-1}, 2^b] -> width 2^b
    b = np.asarray([(int(v) - 1).bit_length() for v in lens[ne]],
                   dtype=np.int64)
    srt = np.argsort(b, kind="stable")
    order, bs = ne[srt], b[srt]
    cut = np.flatnonzero(np.diff(bs)) + 1
    bounds = np.concatenate([[0], cut, [order.size]]).astype(np.int64)
    widths = (np.int64(1) << bs[bounds[:-1]]).astype(np.int64)
    return order, bounds, widths


def _solve_segmented(seg: np.ndarray, t: np.ndarray, s: np.ndarray,
                     start: np.ndarray, pallas: bool = False) -> None:
    """Bucketed evaluation over the flat layout; fills ``start``."""
    lens = np.diff(seg)
    order, bounds, widths = _bucket_rows(lens)
    for bi in range(bounds.size - 1):
        rows = order[bounds[bi]:bounds[bi + 1]]
        w = int(widths[bi])
        r = rows.size
        rl = lens[rows]
        mass = int(rl.sum())
        # flat gather indices for this bucket's rows
        rr = np.repeat(np.arange(r), rl)
        pp = np.arange(mass) - np.repeat(np.cumsum(rl) - rl, rl)
        flat = np.repeat(seg[:-1][rows], rl) + pp
        T = _scratch("T", r * w)[:r * w].reshape(r, w)
        S = _scratch("S", r * w)[:r * w].reshape(r, w)
        # pads sit after each row's data; garbage there never reaches a
        # real row's prefix, so only the data region is written
        T.fill(0.0)
        S.fill(0.0)
        T[rr, pp] = t[flat]
        S[rr, pp] = s[flat]
        if pallas:
            from repro.kernels import ops
            st = np.asarray(ops.lindley(T, S))
            start[flat] = st[rr, pp]
            continue
        C = _scratch("C", r * w)[:r * w].reshape(r, w)
        P = _scratch("P", r * w)[:r * w].reshape(r, w)
        np.cumsum(S, axis=1, out=C)
        np.subtract(C, S, out=P)             # P = within-segment prefix
        np.subtract(T, P, out=C)             # C := T - P (C is free)
        np.maximum.accumulate(C, axis=1, out=C)   # running max, resets/row
        np.add(C, P, out=C)
        np.maximum(T, C, out=C)              # start, padded layout
        start[flat] = C[rr, pp]


def solve_segments(seg: np.ndarray, t: np.ndarray, s: np.ndarray,
                   start: np.ndarray, fin: np.ndarray, *,
                   backend: str = "segmented") -> None:
    """Fill ``start``/``fin`` for every segment's FCFS queue.

    ``seg`` are :func:`segment_fenceposts`; ``t`` (sorted per segment)
    and ``s`` are the flat arrival/service columns.  All three backends
    produce bit-identical results (see the module docstring).
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, "
                         f"got {backend!r}")
    if not t.size:
        return
    if backend == "dense":
        _solve_dense(seg, t, s, start)
    else:
        _solve_segmented(seg, t, s, start, pallas=(backend == "pallas"))
    np.add(start, s, out=fin)


def queue_depth_max(seg: np.ndarray, start: np.ndarray,
                    t: np.ndarray) -> List[int]:
    """Per-segment max queued-copy depth, vectorized across segments.

    Depth is sampled at arrivals (it only grows there): at the ``j``-th
    arrival of a segment the depth is ``j + 1`` minus the number of
    copies already started (``start_i <= t_j``).  Both ``start`` and
    ``t`` are non-decreasing within a segment, so the count is a merge
    rank: sort ``(segment, value, kind)`` with starts ordered before
    arrivals on ties (the ``side='right'`` convention) and count starts
    by cumulative sum — exact, comparison-only, no per-server loop.
    Nonempty segments are pinned to depth >= 1 (the classic engine
    counts the in-service copy whenever the server dispatched at all).
    """
    nserv = seg.size - 1
    m = int(t.size)
    maxd = [0] * nserv
    if not m:
        return maxd
    lens = np.diff(seg)
    seg_id = np.repeat(np.arange(nserv, dtype=np.int64), lens)
    val = np.concatenate([start, t])
    kind = np.zeros(2 * m, dtype=np.int8)
    kind[m:] = 1                            # starts sort before ties
    sid2 = np.concatenate([seg_id, seg_id])
    order = np.lexsort((kind, val, sid2))
    started_cum = np.cumsum(order < m)      # starts seen so far, merged
    p = np.flatnonzero(order >= m)          # merged positions of arrivals
    j = order[p] - m                        # flat arrival index
    depth = np.empty(m, dtype=np.int64)
    depth[j] = j + 1 - started_cum[p]
    ne = np.flatnonzero(lens)
    md = np.maximum.reduceat(depth, seg[:-1][ne]) if ne.size else ne
    for k, d in zip(ne.tolist(), np.maximum(md, 1).tolist()):
        maxd[k] = int(d)
    return maxd
