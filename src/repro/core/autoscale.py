"""Autoscaling control loop over the engine's telemetry (§VII cost story).

The paper's headline is that a 15 W in-storage accelerator beats a 250 W
GPU on end-to-end serverless *cost and energy* — but that comparison only
bites under time-varying load, where a fixed fleet is provisioned for the
peak and burns idle power and amortized CAPEX through every trough.  This
module closes that gap: a control loop steps alongside the discrete-event
engine at fixed epoch boundaries (``ClusterEngine.run_soa(...,
controller=policy)``), reads the engine's live queue-depth/utilization
telemetry as a :class:`~repro.core.engine.FleetSnapshot`, and resizes the
fleet —

  * the **CPU fallback pool** scales by (de)activating nodes: a
    deactivated node takes no new dispatch, drains run-to-completion, then
    powers off;
  * **DSCS drives** power up/down: a powered-off drive woken by an arrival
    (its data lives there — placement never moves) or proactively by the
    controller serves only after the modeled ``dscs_wake_s`` penalty.

Three shipped policies span the classic design space (cf. Hardless,
arXiv 2208.03192, on heterogeneous pool sizing):

  * :class:`StaticPolicy`    — fixed fleet, the paper's (and PR-2's) setting
  * :class:`ReactivePolicy`  — threshold controller on queue depth
    (scale up) and utilization (scale down)
  * :class:`EWMAPolicy`      — predictive: EWMA over the arrival rate,
    provisioned by Little's law with headroom
  * :class:`WorstTenantPolicy` — multi-tenant aware: reads the snapshot's
    per-tenant live backlogs (``FleetSnapshot.tenant_queue``) and sizes
    the pools for the worst-off tenant instead of the fleet aggregate

:func:`evaluate_policy` runs a policy and scores it on the ServerMix-style
(arXiv 1907.11465) axes the evaluation should output: **cost per SLA-met
request** (amortized CAPEX rental of powered servers + metered
electricity, via :mod:`repro.core.cost`) and **energy per request** (busy/
idle server power integrated over the run, via :mod:`repro.core.energy`).
``benchmarks/figures.py::fig20_autoscaling`` sweeps all three policies
under the diurnal and bursty MMPP arrival processes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.cost import (ELECTRICITY_USD_PER_KWH, REPAIR_USD_PER_GB,
                             rental_rate_usd_per_s)
from repro.core.energy import node_power_w
from repro.core.engine import ClusterEngine, FleetSnapshot
from repro.core.faults import FaultPlan
from repro.core.function import Pipeline, is_acceleratable
from repro.core.latency import LatencyModel
from repro.core.platforms import (CPU_FALLBACK_PLATFORM, DSCS_PLATFORM,
                                  PLATFORMS)

__all__ = [
    "AutoscaleAction", "AutoscalePolicy", "AutoscaleReport", "EWMAPolicy",
    "ReactivePolicy", "StaticPolicy", "WorstTenantPolicy", "evaluate_policy",
    "fleet_cost_usd", "fleet_energy_j",
]


@dataclass(frozen=True)
class AutoscaleAction:
    """What a policy asks of the fleet at one epoch: the target number of
    active CPU fallback nodes and of powered DSCS drives.  The engine
    clamps to ``[1, n_cpu_total]`` / ``[0, n_dscs_total]`` and treats
    drive power-down as best-effort (busy or backlogged drives are never
    yanked)."""
    n_cpu: int
    n_dscs_on: int


class AutoscalePolicy:
    """Base class for autoscaling policies.

    Subclasses set ``epoch_s`` (the control period, simulated seconds) and
    implement :meth:`observe`, which receives a
    :class:`~repro.core.engine.FleetSnapshot` at every epoch boundary and
    returns an :class:`AutoscaleAction` (or ``None`` to leave the fleet
    untouched this epoch).  Policies may keep state across epochs;
    :meth:`reset` clears it so one policy object can score several runs.
    """

    name = "base"
    epoch_s: float = 1.0

    def observe(self, snap: FleetSnapshot) -> Optional[AutoscaleAction]:
        """One control step; called by the engine at each epoch boundary."""
        raise NotImplementedError

    def reset(self) -> None:
        """Clear cross-epoch state before a fresh run (no-op by default)."""


class StaticPolicy(AutoscalePolicy):
    """Fixed fleet baseline: pin ``n_cpu`` active nodes and ``n_dscs_on``
    powered drives every epoch.  With the full provisioned fleet this is
    bit-identical to running without a controller (tested), which makes it
    the control arm of the fig20 sweep."""

    name = "static"

    def __init__(self, n_cpu: int, n_dscs_on: int, *, epoch_s: float = 1.0):
        self.n_cpu = n_cpu
        self.n_dscs_on = n_dscs_on
        self.epoch_s = epoch_s

    def observe(self, snap: FleetSnapshot) -> AutoscaleAction:
        return AutoscaleAction(self.n_cpu, self.n_dscs_on)


class ReactivePolicy(AutoscalePolicy):
    """Threshold controller on the engine's queue/utilization telemetry.

    Scale **up** multiplicatively when the live queue depth per powered
    server crosses ``high_water`` (backlog is building faster than the
    pool drains); scale **down** multiplicatively when the pool is nearly
    queue-free *and* its busy fraction sits below ``low_util`` (capacity
    is idling).  CPU nodes and DSCS drives are controlled independently
    with the same rule.
    """

    name = "reactive"

    def __init__(self, *, epoch_s: float = 1.0, high_water: float = 1.0,
                 low_water: float = 0.1, low_util: float = 0.6,
                 grow: float = 1.5, shrink: float = 0.85,
                 min_cpu: int = 1, min_dscs_on: int = 0):
        self.epoch_s = epoch_s
        self.high_water = high_water
        self.low_water = low_water
        self.low_util = low_util
        self.grow = grow
        self.shrink = shrink
        self.min_cpu = min_cpu
        self.min_dscs_on = min_dscs_on

    def _resize(self, current: int, queue: int, busy: int, floor: int,
                ceiling: int) -> int:
        pool = max(1, current)
        depth = queue / pool
        util = busy / pool
        if depth > self.high_water:
            want = max(current + 1, math.ceil(current * self.grow))
        elif depth < self.low_water and util < self.low_util:
            want = math.floor(current * self.shrink)
        else:
            want = current
        return min(ceiling, max(floor, want))

    def observe(self, snap: FleetSnapshot) -> AutoscaleAction:
        return AutoscaleAction(
            n_cpu=self._resize(snap.n_cpu_active, snap.cpu_queue,
                               snap.cpu_busy, self.min_cpu,
                               snap.n_cpu_total),
            n_dscs_on=self._resize(snap.n_dscs_on, snap.dscs_queue,
                                   snap.dscs_busy, self.min_dscs_on,
                                   snap.n_dscs_total))


class EWMAPolicy(AutoscalePolicy):
    """Predictive sizing from a smoothed arrival-rate estimate.

    Each epoch updates an exponentially-weighted moving average of the
    observed arrival rate, splits it into the acceleratable share (served
    by drives) and the CPU share (plus a hedge-duplicate allowance), and
    provisions each pool by Little's law:

        servers = ceil(rate_share * mean_service_s / target_util)

    ``target_util`` < 1 is the headroom that absorbs within-epoch
    stochastic bursts; the EWMA's memory (``alpha``) is what rides the
    diurnal profile instead of chasing every epoch's noise.  Use
    :meth:`for_pipelines` to derive the service-time/share constants from
    the same :class:`~repro.core.latency.LatencyModel` the engine draws
    from.
    """

    name = "ewma"

    def __init__(self, *, cpu_service_s: float, dscs_service_s: float,
                 accel_frac: float, epoch_s: float = 1.0, alpha: float = 0.3,
                 target_util: float = 0.7, hedge_allowance: float = 0.1,
                 min_cpu: int = 1, min_dscs_on: int = 0):
        self.cpu_service_s = cpu_service_s
        self.dscs_service_s = dscs_service_s
        self.accel_frac = accel_frac
        self.epoch_s = epoch_s
        self.alpha = alpha
        self.target_util = target_util
        self.hedge_allowance = hedge_allowance
        self.min_cpu = min_cpu
        self.min_dscs_on = min_dscs_on
        self._rate: Optional[float] = None

    @classmethod
    def for_pipelines(cls, lm: LatencyModel, pipelines: Sequence[Pipeline],
                      **kw) -> "EWMAPolicy":
        """Derive service means (median e2e per platform, averaged over
        the pipeline mix) and the acceleratable share from the latency
        model — the same decomposition the engine samples from."""
        accel = [is_acceleratable(p) for p in pipelines]
        cpu_s = float(np.mean([lm.e2e(PLATFORMS[CPU_FALLBACK_PLATFORM],
                                      p.workload, q=0.5)
                               for p in pipelines]))
        dscs_s = float(np.mean([lm.e2e(PLATFORMS[DSCS_PLATFORM], p.workload,
                                       q=0.5) for p in pipelines]))
        return cls(cpu_service_s=cpu_s, dscs_service_s=dscs_s,
                   accel_frac=float(np.mean(accel)), **kw)

    def reset(self) -> None:
        self._rate = None

    def observe(self, snap: FleetSnapshot) -> AutoscaleAction:
        rate = snap.arrivals / self.epoch_s
        if self._rate is None:
            self._rate = rate
        else:
            self._rate = self.alpha * rate + (1.0 - self.alpha) * self._rate
        accel_rate = self._rate * self.accel_frac
        # hedged duplicates of accelerated requests land on the CPU pool
        cpu_rate = (self._rate * (1.0 - self.accel_frac)
                    + accel_rate * self.hedge_allowance)
        n_cpu = math.ceil(cpu_rate * self.cpu_service_s / self.target_util)
        n_dscs = math.ceil(accel_rate * self.dscs_service_s
                           / self.target_util)
        return AutoscaleAction(
            n_cpu=min(snap.n_cpu_total, max(self.min_cpu, n_cpu)),
            n_dscs_on=min(snap.n_dscs_total, max(self.min_dscs_on, n_dscs)))


class WorstTenantPolicy(ReactivePolicy):
    """Reactive scaling driven by the *worst-off tenant*, not the fleet
    aggregate.

    On multi-tenant runs the engine's :class:`~repro.core.engine.
    FleetSnapshot` carries per-tenant live backlogs (``tenant_queue``).
    A fleet-level average can look healthy while one tenant drowns behind
    a noisy neighbor; this policy sizes both pools as if *every* tenant
    were as backlogged as the worst one (``max(tenant_queue) * n_tenants``
    replaces the aggregate queue in the scale-up rule), so isolation
    pressure, not mean load, drives capacity.  On single-tenant runs
    (empty ``tenant_queue``) it degrades to plain :class:`ReactivePolicy`.
    """

    name = "worst-tenant"

    def observe(self, snap: FleetSnapshot) -> AutoscaleAction:
        if not snap.tenant_queue:
            return super().observe(snap)
        worst = max(snap.tenant_queue) * len(snap.tenant_queue)
        # per-tenant backlogs aggregate both classes; split the pessimistic
        # total across the pools in proportion to their live queues
        total = max(1, snap.dscs_queue + snap.cpu_queue)
        dscs_q = math.ceil(worst * snap.dscs_queue / total)
        cpu_q = math.ceil(worst * snap.cpu_queue / total)
        return AutoscaleAction(
            n_cpu=self._resize(snap.n_cpu_active, cpu_q, snap.cpu_busy,
                               self.min_cpu, snap.n_cpu_total),
            n_dscs_on=self._resize(snap.n_dscs_on, dscs_q, snap.dscs_busy,
                                   self.min_dscs_on, snap.n_dscs_total))


# --------------------------------------------------------------------------
# evaluation: cost per SLA-met request + energy per request
# --------------------------------------------------------------------------

def fleet_energy_j(power_stats: Dict[str, object]) -> Dict[str, float]:
    """Fleet energy from the engine's ``power_stats()``: busy seconds at
    each platform's active power plus powered-idle seconds at its idle
    power (:func:`repro.core.energy.node_power_w`); powered-off servers
    draw nothing."""
    out: Dict[str, float] = {}
    for cls, plat_name in (("cpu", CPU_FALLBACK_PLATFORM),
                           ("dscs", DSCS_PLATFORM)):
        plat = PLATFORMS[plat_name]
        st = power_stats[cls]
        busy = float(st["busy_s"])
        idle = max(0.0, float(st["powered_s"]) - busy)
        out[cls] = (busy * node_power_w(plat, True)
                    + idle * node_power_w(plat, False))
    out["total"] = out["cpu"] + out["dscs"]
    return out


def fleet_cost_usd(power_stats: Dict[str, object], energy_j: float,
                   repair_bytes: float = 0.0) -> Dict[str, float]:
    """Fleet cost over the run: powered server-seconds priced at each
    platform's amortized CAPEX rental rate
    (:func:`repro.core.cost.rental_rate_usd_per_s`) plus metered
    electricity for the consumed energy, plus re-replication traffic
    (``repair_bytes``, from the engine's ``fault_stats()``) priced at
    :data:`repro.core.cost.REPAIR_USD_PER_GB` — so a policy that
    power-cycles drives is charged for the repair bytes it triggers."""
    out = {
        "cpu_capex": (rental_rate_usd_per_s(PLATFORMS[CPU_FALLBACK_PLATFORM])
                      * float(power_stats["cpu"]["powered_s"])),
        "dscs_capex": (rental_rate_usd_per_s(PLATFORMS[DSCS_PLATFORM])
                       * float(power_stats["dscs"]["powered_s"])),
        "electricity": energy_j / 3.6e6 * ELECTRICITY_USD_PER_KWH,
        "repair": repair_bytes / 1e9 * REPAIR_USD_PER_GB,
    }
    out["total"] = (out["cpu_capex"] + out["dscs_capex"]
                    + out["electricity"] + out["repair"])
    return out


@dataclass(frozen=True)
class AutoscaleReport:
    """Scorecard of one policy run — the run summary fig20 sweeps.

    ``mean_cpu_active`` / ``mean_dscs_on`` are powered server-seconds over
    the horizon (time-average fleet size); ``cost_per_sla_req_usd`` is the
    headline ServerMix-style metric (infinite when nothing met the SLA).
    """
    policy: str
    n_requests: int
    sla_met: int
    sla_frac: float
    p50_s: float
    p99_s: float
    horizon_s: float
    mean_cpu_active: float
    mean_dscs_on: float
    wake_events: int
    epochs: int
    energy_j: float
    energy_per_req_j: float
    cost_usd: float
    cost_per_sla_req_usd: float
    repair_gb: float = 0.0


def evaluate_policy(policy: AutoscalePolicy, pipelines: Sequence[Pipeline], *,
                    arrivals: ArrivalProcess, duration_s: float,
                    n_dscs: int, n_cpu: int, sla_s: float,
                    hedge_budget_s: Optional[float] = None, seed: int = 0,
                    latency_model: Optional[LatencyModel] = None,
                    dscs_wake_s: float = 0.2, tier=None,
                    faults: Optional[FaultPlan] = None,
                    timeout_s: Optional[float] = None,
                    overload=None) -> AutoscaleReport:
    """Run ``policy`` over a fresh engine and score it.

    ``n_dscs``/``n_cpu`` are the provisioned maxima the policy scales
    within; everything stochastic derives from ``seed``, so two policies
    evaluated with equal seeds face the identical arrival stream and
    service-tail draws — the comparison isolates the control decision.
    ``tier`` optionally attaches a :class:`~repro.core.tiering.TierConfig`
    (replica routing prefers powered drives, so the tier composes with
    power cycling); ``None`` keeps the classic placement path.
    ``faults`` attaches a :class:`~repro.core.faults.FaultPlan`; when its
    repair model is enabled (and the tier carries an object catalog), a
    policy decision that powers a drive off triggers the same replica
    repair as a fail-stop, and those repair bytes are charged to the cost
    scorecard (``repair_gb``, priced in :func:`fleet_cost_usd`) — power
    cycling is no longer free.  ``timeout_s`` adds per-request deadlines;
    abandoned requests never count as SLA-met.  ``overload`` attaches an
    :class:`~repro.core.overload.OverloadControl`; rejected/shed requests
    never count as SLA-met either, and the policy's ``observe`` sees the
    per-epoch rejection and pushback signals on its
    :class:`~repro.core.engine.FleetSnapshot`.
    """
    policy.reset()
    eng = ClusterEngine(n_dscs=n_dscs, n_cpu=n_cpu,
                        latency_model=latency_model,
                        hedge_budget_s=hedge_budget_s, seed=seed,
                        dscs_wake_s=dscs_wake_s, tier=tier, faults=faults,
                        overload=overload)
    trace = eng.run_soa(pipelines, arrivals=arrivals, duration_s=duration_s,
                        controller=policy, timeout_s=timeout_s)
    ps = eng.power_stats()
    energy = fleet_energy_j(ps)
    fstats = eng.fault_stats()
    repair_bytes = (float(fstats["repair"]["bytes"])
                    if fstats and fstats.get("enabled") else 0.0)
    cost = fleet_cost_usd(ps, energy["total"], repair_bytes)
    n = trace.n
    lat = trace.latency
    lat = lat[~np.isnan(lat)]           # abandoned requests: no latency
    sla_met = int(np.count_nonzero(lat <= sla_s)) if n else 0
    horizon = float(ps["horizon"])
    return AutoscaleReport(
        policy=getattr(policy, "name", type(policy).__name__),
        n_requests=n, sla_met=sla_met,
        sla_frac=sla_met / n if n else 1.0,
        p50_s=(float(np.percentile(lat, 50)) if lat.size
               else (0.0 if not n else math.inf)),
        p99_s=(float(np.percentile(lat, 99)) if lat.size
               else (0.0 if not n else math.inf)),
        horizon_s=horizon,
        mean_cpu_active=(float(ps["cpu"]["powered_s"]) / horizon
                         if horizon > 0 else 0.0),
        mean_dscs_on=(float(ps["dscs"]["powered_s"]) / horizon
                      if horizon > 0 else 0.0),
        wake_events=int(ps["wake_events"]), epochs=int(ps["epochs"]),
        energy_j=energy["total"],
        energy_per_req_j=energy["total"] / n if n else 0.0,
        cost_usd=cost["total"],
        cost_per_sla_req_usd=(cost["total"] / sla_met if sla_met
                              else math.inf),
        repair_gb=repair_bytes / 1e9)
