"""Overload control & metastable-failure resilience for the cluster engine.

The fault layer (:mod:`repro.core.faults`) lets the fleet survive
*failures*; this module defends it against *overload*.  Without it, every
arrival is admitted, queues are unbounded, and ``ExponentialBackoff``
re-dispatch can amplify a transient spike into a retry storm — the classic
metastable congestion collapse real serverless platforms prevent with
concurrency limits and throttling.  Four cooperating mechanisms, all value
objects the engine interprets (like the drive schedulers in
:mod:`repro.core.tenancy`):

  * **Admission control** (applied at arrival time, before placement):
    :class:`AdmitAll` (unconditional baseline), :class:`TokenBucket`
    (deterministic refill, optionally per request class, with per-tenant
    shares proportional to tenant weight), or :class:`QueueThreshold`
    (reject when fleet queue depth per active server, or busy-server
    utilization, exceeds a threshold).
  * **SLA-aware load shedding** inside the drive/CPU queues
    (:class:`ShedPolicy`): bounded queue lengths with a drop-oldest or
    drop-incoming overflow victim, deadline-hopeless dispatch shedding
    (a copy that cannot meet its deadline even with zero further wait is
    dropped instead of served), and CoDel-style sojourn-time shedding
    (persistently above-target queueing delay sheds at dispatch).
  * **Backpressure** (:class:`Backpressure`): at control-epoch boundaries
    the engine derives a pushback factor in ``[min_factor, 1]`` from the
    live queue depth; arrivals are deterministically thinned by that
    factor (modeling client-side throttling) and the factor timeline is
    recorded so an :class:`ThrottledArrivals` wrapper can replay the
    throttling open-loop.  Retries consult the same admission gate, so
    backoff cannot storm a saturated fleet.
  * **Brownout degradation** (:class:`Brownout`): under sustained
    overload (queue depth above ``on_depth`` for ``min_epochs``
    consecutive control epochs) hedging is suspended — requests degrade
    to the cheaper single-copy path — until depth falls back below
    ``off_depth`` (hysteresis).

**Continuity rule**: every policy here is a *deterministic function of
engine state* — token-bucket refill, queue-depth thresholds, sojourn
times, the pushback accumulator.  No random draw is ever taken, so the
layer spawns no SeedSequence child at all, and a disabled layer
(``overload=None`` or a config with every mechanism off) is trivially
bit-identical to the golden traces.

Telemetry lands in :meth:`ClusterEngine.overload_stats`
(admitted/rejected/shed per class and tenant, the pushback timeline,
brownout epochs); sharded fallback runs merge per-shard books through
:func:`merge_overload_stats`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.arrivals import ArrivalProcess

__all__ = ["AdmissionPolicy", "AdmitAll", "TokenBucket", "QueueThreshold",
           "ShedPolicy", "Backpressure", "Brownout", "OverloadControl",
           "ThrottledArrivals", "merge_overload_stats"]

#: Request classes the per-class books are keyed by, in index order.
CLASSES = ("accel", "plain")


# -- admission policies ------------------------------------------------------
@dataclass(frozen=True)
class AdmissionPolicy:
    """Base marker for arrival-time admission policies."""
    name = "admission"

    def validate(self) -> None:
        return None


@dataclass(frozen=True)
class AdmitAll(AdmissionPolicy):
    """Unconditional admission — the naive baseline every real platform
    starts from (and the collapse mode fig24 measures)."""
    name = "admit_all"


@dataclass(frozen=True)
class TokenBucket(AdmissionPolicy):
    """Deterministic token-bucket admission.

    The bucket starts full (``burst`` tokens) and refills continuously at
    ``rate`` tokens/second; each admitted request consumes one token and
    an arrival finding less than one token is rejected.  With
    ``per_class=True`` the acceleratable and plain classes meter through
    independent buckets (each with the full ``rate``/``burst``); on
    multi-tenant runs every tenant gets its own bucket scaled to its
    weight share (``rate * w_k / sum(w)``), so a greedy tenant exhausts
    only its own allocation.
    """
    name = "token_bucket"
    rate: float = 100.0                 # tokens (admissions) per second
    burst: float = 16.0                 # bucket capacity
    per_class: bool = False

    def validate(self) -> None:
        if self.rate <= 0.0:
            raise ValueError("TokenBucket.rate must be positive")
        if self.burst < 1.0:
            raise ValueError("TokenBucket.burst must be >= 1 (a smaller "
                             "bucket could never admit anything)")


@dataclass(frozen=True)
class QueueThreshold(AdmissionPolicy):
    """Reject arrivals when the fleet looks saturated.

    ``max_queue_per_server`` rejects while the live queued-request count
    per active server exceeds the threshold; ``max_utilization`` rejects
    while the busy-server fraction exceeds it.  Either may be ``None``
    (unused); both set means *either* trips rejection.
    """
    name = "queue_threshold"
    max_queue_per_server: Optional[float] = 4.0
    max_utilization: Optional[float] = None

    def validate(self) -> None:
        if self.max_queue_per_server is None and self.max_utilization is None:
            raise ValueError("QueueThreshold needs max_queue_per_server "
                             "and/or max_utilization")
        if self.max_queue_per_server is not None \
                and self.max_queue_per_server < 0.0:
            raise ValueError("max_queue_per_server must be >= 0")
        if self.max_utilization is not None \
                and not 0.0 < self.max_utilization <= 1.0:
            raise ValueError("max_utilization must be in (0, 1]")


# -- load shedding -----------------------------------------------------------
@dataclass(frozen=True)
class ShedPolicy:
    """SLA-aware shedding inside the drive/CPU queues.

    ``max_queue`` bounds every per-server queue's *live* depth; an
    arrival (or retry/hedge copy) finding the queue full sheds the
    oldest live queued copy to make room (``drop="oldest"``) or is
    itself dropped (``drop="incoming"``).  ``hopeless=True`` sheds, at
    dispatch time, any copy that cannot meet its ``timeout_s`` deadline
    even if served immediately (judged against the service-time floor —
    the deterministic component of the copy's service model), instead of
    burning a server on a request that is already lost.
    ``codel_target_s`` enables CoDel-style shedding: when the sojourn
    time (dispatch minus arrival) of dequeued copies stays above the
    target for a full ``codel_interval_s``, copies are shed at dispatch
    until sojourn falls back under the target.
    """
    max_queue: Optional[int] = None
    drop: str = "oldest"                # bounded-queue overflow victim
    hopeless: bool = False              # shed deadline-hopeless at dispatch
    codel_target_s: Optional[float] = None
    codel_interval_s: float = 0.1

    def validate(self) -> None:
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError("ShedPolicy.max_queue must be >= 1")
        if self.drop not in ("oldest", "incoming"):
            raise ValueError("ShedPolicy.drop must be 'oldest' or "
                             "'incoming'")
        if self.codel_target_s is not None and self.codel_target_s <= 0.0:
            raise ValueError("codel_target_s must be positive")
        if self.codel_interval_s <= 0.0:
            raise ValueError("codel_interval_s must be positive")

    @property
    def enabled(self) -> bool:
        return (self.max_queue is not None or self.hopeless
                or self.codel_target_s is not None)


# -- backpressure ------------------------------------------------------------
@dataclass(frozen=True)
class Backpressure:
    """Per-epoch pushback to the arrival sources.

    At every control-epoch boundary the engine computes the live queued
    requests per active server, ``depth``, and sets the pushback factor

        ``f = clamp(target_depth / depth, min_factor, 1.0)``

    (``f = 1`` while ``depth <= target_depth``).  Arrivals in the next
    epoch are thinned deterministically to a fraction ``f`` (an
    accumulator admits every request while ``f = 1`` and exactly ``f`` of
    them otherwise — modeling clients honoring a throttle signal); the
    ``(t, f)`` timeline is recorded in ``overload_stats()`` and can be
    replayed open-loop through :class:`ThrottledArrivals`.
    """
    target_depth: float = 4.0           # live queued per active server
    min_factor: float = 0.05            # floor: never silence clients fully

    def validate(self) -> None:
        if self.target_depth <= 0.0:
            raise ValueError("Backpressure.target_depth must be positive")
        if not 0.0 < self.min_factor <= 1.0:
            raise ValueError("Backpressure.min_factor must be in (0, 1]")


# -- brownout ----------------------------------------------------------------
@dataclass(frozen=True)
class Brownout:
    """Sustained-overload degradation with hysteresis.

    Brownout engages after the live queue depth per active server has
    been at or above ``on_depth`` for ``min_epochs`` consecutive control
    epochs, and disengages once depth falls to or below ``off_depth``
    (which must be below ``on_depth``).  While engaged, hedging is
    suspended — requests run the cheaper single-copy path — shedding the
    duplicate-work amplification exactly when the fleet can least afford
    it.  (Failure-*detection* hedges from a
    :class:`~repro.core.faults.FaultPlan` watchdog stay active: they
    rescue stuck requests rather than shave tails.)
    """
    on_depth: float = 8.0
    off_depth: float = 2.0
    min_epochs: int = 2

    def validate(self) -> None:
        if self.on_depth <= 0.0:
            raise ValueError("Brownout.on_depth must be positive")
        if not 0.0 <= self.off_depth < self.on_depth:
            raise ValueError("Brownout.off_depth must be in "
                             "[0, on_depth) for hysteresis")
        if self.min_epochs < 1:
            raise ValueError("Brownout.min_epochs must be >= 1")


# -- the composite config ----------------------------------------------------
@dataclass(frozen=True)
class OverloadControl:
    """The overload-control layer: any subset of the four mechanisms.

    ``epoch_s`` is the control period for backpressure/brownout
    evaluation (admission and shedding act per event, not per epoch).
    A config with every mechanism off (or ``overload=None``) keeps the
    classic bit-exact path — see the module docstring's continuity rule.
    """
    admission: Optional[AdmissionPolicy] = None
    shed: Optional[ShedPolicy] = None
    backpressure: Optional[Backpressure] = None
    brownout: Optional[Brownout] = None
    epoch_s: float = 0.25

    @property
    def enabled(self) -> bool:
        return (
            (self.admission is not None
             and not isinstance(self.admission, AdmitAll))
            or (self.shed is not None and self.shed.enabled)
            or self.backpressure is not None
            or self.brownout is not None)

    def validate(self) -> None:
        if self.epoch_s <= 0.0:
            raise ValueError("OverloadControl.epoch_s must be positive")
        if self.admission is not None:
            if not isinstance(self.admission, AdmissionPolicy):
                raise TypeError(f"unknown admission policy: "
                                f"{self.admission!r}")
            self.admission.validate()
        if self.shed is not None:
            self.shed.validate()
        if self.backpressure is not None:
            self.backpressure.validate()
        if self.brownout is not None:
            self.brownout.validate()


# -- open-loop pushback replay ----------------------------------------------
@dataclass(frozen=True)
class ThrottledArrivals(ArrivalProcess):
    """An :class:`ArrivalProcess` wrapper honoring a pushback timeline.

    ``timeline`` is a sequence of ``(t, factor)`` pairs (exactly what
    ``overload_stats()["pushback"]["timeline"]`` records): from time
    ``t`` on, clients emit only a ``factor`` fraction of the inner
    process's arrivals, thinned by the same deterministic accumulator
    the engine's closed-loop gate uses — so replaying a run's recorded
    timeline open-loop reproduces the engine's admitted-by-pushback
    stream.  Before the first breakpoint the factor is 1.0.
    """
    rate: float = -1.0
    inner: Optional[ArrivalProcess] = None
    timeline: Tuple[Tuple[float, float], ...] = ()

    def __post_init__(self) -> None:
        if self.inner is None:
            raise ValueError("ThrottledArrivals needs an inner process")
        tl = tuple((float(t), float(f)) for t, f in self.timeline)
        if any(t1 < t0 for (t0, _), (t1, _) in zip(tl, tl[1:])):
            raise ValueError("timeline breakpoints must be sorted by time")
        if any(not 0.0 <= f <= 1.0 for _, f in tl):
            raise ValueError("pushback factors must be in [0, 1]")
        object.__setattr__(self, "timeline", tl)
        if self.rate < 0.0:
            object.__setattr__(self, "rate", float(self.inner.rate))

    def times(self, duration_s: float,
              rng: np.random.Generator) -> np.ndarray:
        ts = self.inner.times(duration_s, rng)
        if not ts.size or not self.timeline:
            return ts
        keep = np.zeros(ts.size, dtype=bool)
        bps = self.timeline
        j = -1                          # active breakpoint (-1 = factor 1.0)
        acc = 0.0
        for i, t in enumerate(ts.tolist()):
            while j + 1 < len(bps) and bps[j + 1][0] <= t:
                j += 1
            f = bps[j][1] if j >= 0 else 1.0
            if f >= 1.0:
                keep[i] = True
                continue
            acc += f
            if acc >= 1.0:
                acc -= 1.0
                keep[i] = True
        return ts[keep]

    def with_rate(self, rate: float) -> "ArrivalProcess":
        return ThrottledArrivals(rate=rate,
                                 inner=self.inner.with_rate(rate),
                                 timeline=self.timeline)


# -- sharded merge -----------------------------------------------------------
def merge_overload_stats(states: Sequence[Optional[dict]]
                         ) -> Optional[dict]:
    """Merge per-shard ``overload_stats()`` dicts into one fleet view.

    Counters sum; the pushback timelines concatenate (tagged with the
    shard index, since each shard ran its own control loop); brownout
    epoch counts sum.  ``None`` in means that shard ran without the
    layer — all-``None`` merges to ``None``.
    """
    live = [s for s in states if s is not None]
    if not live:
        return None
    out = {
        "enabled": True,
        "admitted": sum(s["admitted"] for s in live),
        "rejected": sum(s["rejected"] for s in live),
        "shed": sum(s["shed"] for s in live),
        "copies_cancelled": sum(s["copies_cancelled"] for s in live),
        "rejected_by": {
            k: sum(s["rejected_by"][k] for s in live)
            for k in live[0]["rejected_by"]},
        "shed_by": {k: sum(s["shed_by"][k] for s in live)
                    for k in live[0]["shed_by"]},
        "per_class": {
            c: {k: sum(s["per_class"][c][k] for s in live)
                for k in live[0]["per_class"][c]}
            for c in live[0]["per_class"]},
        "per_tenant": None,
        "retries_denied": sum(s["retries_denied"] for s in live),
        "hedges_suppressed": sum(s["hedges_suppressed"] for s in live),
        "brownout": {
            "entered": sum(s["brownout"]["entered"] for s in live),
            "active_epochs": sum(s["brownout"]["active_epochs"]
                                 for s in live),
            "intervals": [iv for s in live
                          for iv in s["brownout"]["intervals"]],
        },
        "pushback": {
            "timeline": [(sh, t, f) for sh, s in enumerate(states)
                         if s is not None
                         for t, f in s["pushback"]["timeline"]],
            "final": min(s["pushback"]["final"] for s in live),
        },
        "epochs": sum(s["epochs"] for s in live),
    }
    offered = sum(s["goodput"]["offered"] for s in live)
    completed = sum(s["goodput"]["completed"] for s in live)
    out["goodput"] = {"offered": offered, "completed": completed,
                      "goodput_frac": completed / offered if offered else 0.0}
    return out
