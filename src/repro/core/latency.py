"""End-to-end latency model, calibrated to the paper's AWS characterization.

Components (§II, §VI-A):
  * remote storage read/write — S3-style RPC: base latency + size/bw, with
    lognormal tails (Fig. 5: p99/p50 ~ 2.1x reads, ~1.75x writes)
  * ProtoBuf (de)serialization at the storage node
  * read/write syscall + NVMe I/O over PCIe at the storage node
  * serverless system stack (OpenFaaS + Kubernetes dispatch, warm container)
  * PCIe DMA to a discrete accelerator (cudaMemcpy-style) on compute nodes
  * P2P PCIe between flash and the near-storage device (SmartSSD-measured)
  * device driver overhead for near-storage offload (O(ms), §VI-B)
  * cold start: image pull + unpack + health check + weight load

Compute times come from the DSA tile model (dsa.py) for the DSA and from a
peak*efficiency model (batch-1 underutilization per platform) otherwise.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.core.dsa import DSAConfig, network_latency_s
from repro.core.platforms import PCIE_GBPS, Platform
from repro.core.workloads import Workload


@dataclass
class LatencyParams:
    rpc_base_s: float = 12e-3           # S3 REST round-trip (same region)
    get_bw: float = 95e6                # B/s per-object GET
    put_bw: float = 60e6                # B/s per-object PUT
    read_sigma: float = 0.42            # lognormal sigma -> p99/p50 ~ 2.1x
    write_sigma: float = 0.30           # -> p99/p50 ~ 1.75x
    proto_bw: float = 1.2e9             # protobuf (de)serialize
    proto_base_s: float = 3e-4
    syscall_s: float = 1.5e-4
    nvme_bw: float = 3.0e9
    stack_s: float = 9e-3               # OpenFaaS+K8s dispatch, warm
    notify_s: float = 4e-3              # f3 notification service work
    pcie_base_s: float = 1e-5
    p2p_base_s: float = 3e-5
    driver_s: float = 1.3e-3            # NS offload driver (O(ms))
    dsa_invoke_s: float = 5e-5
    # cold start: the image layer is cached node-locally (registry mirror)
    # and the paper ships model weights inside the container image, so the
    # cold path = container start + health check + loading weights into the
    # device (NVMe for CPU/GPU nodes, P2P for the CSD).
    image_unpack_s: float = 0.08
    health_check_s: float = 0.04
    preprocess_flops_per_byte: float = 60.0
    # tiered data layer (tiering.py): a cache hit serves the payload from
    # drive DRAM instead of flash P2P + NS driver; a cache fill pulls the
    # object from the remote backing store (S3-class bandwidth).
    cache_dram_bw: float = 12e9         # B/s drive-DRAM payload read
    cache_hit_base_s: float = 2e-5      # lookup + DMA setup on a hit
    backing_base_s: float = 15e-3       # backing object-store RTT
    backing_bw: float = 80e6            # B/s backing-store GET


@dataclass
class LatencyModel:
    params: LatencyParams = field(default_factory=LatencyParams)
    pcie_lanes: str = "gen3x4"          # P2P link inside the CSD
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)

    # --- stochastic network components -------------------------------------
    def _tail(self, sigma: float, q: Optional[float]) -> float:
        """Lognormal multiplier; q=None -> sample, else quantile."""
        if q is None:
            return float(np.exp(self.rng.normal(0.0, sigma)))
        return float(np.exp(sigma * math.sqrt(2.0) *
                            _erfinv(2.0 * q - 1.0)))

    def net_read(self, nbytes: int, q: Optional[float] = 0.5) -> float:
        p = self.params
        base = (p.rpc_base_s + nbytes / p.get_bw
                + p.proto_base_s + nbytes / p.proto_bw      # deserialization
                + p.syscall_s + nbytes / p.nvme_bw)         # storage-side IO
        return base * self._tail(p.read_sigma, q)

    def net_write(self, nbytes: int, q: Optional[float] = 0.5) -> float:
        p = self.params
        base = (p.rpc_base_s + nbytes / p.put_bw
                + p.proto_base_s + nbytes / p.proto_bw
                + p.syscall_s + nbytes / p.nvme_bw)
        return base * self._tail(p.write_sigma, q)

    # --- deterministic local components -------------------------------------
    def pcie(self, nbytes: int, lanes: str) -> float:
        return self.params.pcie_base_s + nbytes / PCIE_GBPS[lanes]

    def p2p(self, nbytes: int) -> float:
        return self.params.p2p_base_s + nbytes / PCIE_GBPS[self.pcie_lanes]

    # --- tiered data layer (tiering.py) --------------------------------------
    def dram_read(self, nbytes: int) -> float:
        """Serve a cached payload from drive DRAM (the cache-hit read)."""
        p = self.params
        return p.cache_hit_base_s + nbytes / p.cache_dram_bw

    def cache_hit_savings(self, nbytes: int) -> float:
        """Service-time delta of a DRAM cache hit on the near-storage read
        path: the flash P2P transfer and the NS driver invocation are
        replaced by a DRAM read.  Never negative."""
        return max(0.0, self.p2p(nbytes) + self.params.driver_s
                   - self.dram_read(nbytes))

    def backing_fetch(self, nbytes: int) -> float:
        """One-time cost of materializing an object from the remote backing
        store onto a drive (lazy replica / migration fill)."""
        p = self.params
        return p.backing_base_s + nbytes / p.backing_bw

    # --- compute -------------------------------------------------------------
    def compute_s(self, plat: Platform, wl: Workload, batch: int = 1,
                  dsa_cfg: Optional[DSAConfig] = None) -> float:
        if plat.kind == "dsa":
            cfg = dsa_cfg or DSAConfig(mem_bw=plat.mem_bw,
                                       freq_hz=plat.freq_hz)
            from repro.core.workloads import GemmShape
            gemms = [GemmShape(g.m * batch, g.k, g.n, g.vector_ops * batch)
                     for g in wl.gemms]
            return network_latency_s(cfg, gemms)
        eff = plat.batch1_efficiency + (plat.sat_efficiency - plat.batch1_efficiency) * min(
            1.0, (batch - 1) / max(plat.batch_saturation - 1, 1))
        t_flops = batch * wl.flops / (plat.peak_flops * eff)
        # weights stream from device memory once per request (batch amortizes)
        t_mem = (wl.weight_bytes + batch * wl.input_bytes) / plat.mem_bw
        t_launch = len(wl.gemms) * plat.launch_s
        return max(t_flops, t_mem) + t_launch

    def preprocess_s(self, plat: Platform, wl: Workload, batch: int = 1) -> float:
        flops = wl.request_bytes * self.params.preprocess_flops_per_byte * batch
        if plat.kind == "dsa":   # vector engine: 8x128 lanes @ freq
            return flops / (8 * 128 * plat.freq_hz) + self.params.dsa_invoke_s
        thr = plat.peak_flops * 0.05 if plat.kind != "cpu" else plat.peak_flops * 0.2
        return flops / thr

    # --- end-to-end composition ----------------------------------------------
    def pipeline_breakdown(self, plat: Platform, wl: Workload, *,
                           batch: int = 1, q: Optional[float] = 0.5,
                           dsa_cfg: Optional[DSAConfig] = None,
                           extra_accel_funcs: int = 0,
                           cold: bool = False,
                           cache_hit: bool = False) -> Dict[str, float]:
        """Latency breakdown for the 3-function pipeline (Fig. 2) on one
        platform.  Returns component -> seconds (Fig. 4 / Fig. 9 analogue).

        ``cache_hit`` (near-storage only) serves the request payload from
        the drive's DRAM cache instead of flash P2P + NS driver.
        """
        p = self.params
        bd: Dict[str, float] = {"stack": 0.0, "net": 0.0, "io": 0.0,
                                "compute": 0.0, "driver": 0.0, "cold": 0.0}
        inp = wl.request_bytes * batch
        mid = wl.input_bytes * batch
        out = wl.output_bytes * batch

        if plat.location == "remote":
            # f1: stack + read request + preprocess + write tensor
            bd["stack"] += p.stack_s
            bd["net"] += self.net_read(inp, q) + self.net_write(mid, q)
            bd["compute"] += self.preprocess_s(plat, wl, batch)
            # f2 (+ replicas): stack + read tensor + [pcie in] + infer +
            # [pcie out] + write result
            for _ in range(1 + extra_accel_funcs):
                bd["stack"] += p.stack_s
                bd["net"] += self.net_read(mid, q) + self.net_write(out, q)
                if plat.kind != "cpu":
                    bd["io"] += (self.pcie(mid, plat.pcie)
                                 + self.pcie(out, plat.pcie))
                    bd["driver"] += p.driver_s
                bd["compute"] += self.compute_s(plat, wl, batch, dsa_cfg)
        else:
            # near-storage: f1+f2 run at the drive over P2P; no network for
            # intermediates
            bd["stack"] += p.stack_s                 # dispatch to storage node
            if cache_hit:
                bd["io"] += self.dram_read(inp)      # payload from drive DRAM
            else:
                bd["io"] += self.p2p(inp)
                bd["driver"] += p.driver_s
            bd["compute"] += self.preprocess_s(plat, wl, batch)
            for _ in range(1 + extra_accel_funcs):
                bd["compute"] += self.compute_s(plat, wl, batch, dsa_cfg)
                if plat.kind == "dsa":
                    bd["driver"] += p.dsa_invoke_s
            bd["io"] += self.p2p(out)

        # f3: notification service on a CPU node — reads result remotely
        # in BOTH designs (paper §VI-B runtime-breakdown discussion)
        bd["stack"] += p.stack_s
        bd["net"] += self.net_read(out, q)
        bd["compute"] += p.notify_s

        if cold:
            bd["cold"] = (p.image_unpack_s + p.health_check_s
                          + (self.p2p(wl.weight_bytes)
                             if plat.location == "near_storage"
                             else wl.weight_bytes / p.nvme_bw))
        bd["total"] = sum(v for k, v in bd.items() if k != "total")
        return bd

    def e2e(self, plat: Platform, wl: Workload, **kw) -> float:
        return self.pipeline_breakdown(plat, wl, **kw)["total"]


def _erfinv(x: float) -> float:
    """Winitzki approximation (|err| < 6e-3) — good enough for quantiles."""
    a = 0.147
    ln = math.log(1.0 - x * x)
    t = 2.0 / (math.pi * a) + ln / 2.0
    return math.copysign(math.sqrt(math.sqrt(t * t - ln / a) - t), x)
