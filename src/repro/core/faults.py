"""Fault injection & failure recovery (robustness layer, ISSUE 7).

The fleet simulator modeled drives and CPU nodes as infallible, so every
headline figure silently assumed 100% availability.  This module supplies
the dependability vocabulary the engine interprets
(``ClusterEngine(faults=FaultPlan(...))``):

  * **fault taxonomy** — four injectable fault kinds, either listed
    explicitly (:class:`DriveFailure`, :class:`DriveStall`,
    :class:`CpuCrash`) or generated from per-class MTBF/MTTR knobs on the
    plan; plus a per-fetch backing-store failure probability:

      - *drive fail-stop*: the drive vanishes; queued and in-flight
        requests are lost, its materialized objects are gone (a repaired/
        replaced drive comes back empty and refills lazily).
      - *drive stall* (gray failure): the drive keeps serving but every
        service started inside the window runs ``factor`` x slower.
      - *CPU node crash*: the fallback node vanishes; its queued and
        running copies are lost.  A crash that would leave zero live CPU
        nodes is skipped (and counted), so the fallback path always
        exists.
      - *backing-store fetch failure*: each remote fetch independently
        fails with probability ``backing_fail_p``; every failed attempt
        costs ``backing_retry_s`` before the retry succeeds.

  * **retry with backoff** — a pluggable :class:`RetryPolicy` decides how
    a lost request is re-dispatched: :class:`NoRetry` (the request is
    abandoned), :class:`FixedRetry` (constant delay), or
    :class:`ExponentialBackoff` with *decorrelated jitter*
    (``delay = min(cap, U(base, 3 * prev))``, the AWS-architecture-blog
    scheme the Lithops/ServerMix executors use), all under a
    ``max_attempts`` cap and an optional fleet-wide :class:`RetryBudget`
    circuit breaker (retries stop when they exceed a fraction of the
    arrivals seen so far, so retry storms cannot melt a degraded fleet).

  * **repair** — a :class:`RepairModel` re-replicates the objects that
    lost a replica (drive failure, or an autoscaler power-down — the
    ROADMAP follow-on) onto surviving drives through one serialized
    repair pipe of ``bandwidth_bps``; the replica table is patched when
    the transfer completes, and the moved bytes/seconds are reported so
    :func:`repro.core.autoscale.evaluate_policy` can charge them to the
    cost model.

  * **timeout-based failure detection** — ``detect_timeout_s`` arms a
    watchdog per DSCS dispatch: a request still unfinished that long
    after dispatch gets a CPU hedge copy, so a stalled (not failed) drive
    is routed around before the stall clears.  Per-request
    ``timeout_s`` deadline abandonment is independent of this module
    (``ClusterEngine.run_soa(timeout_s=...)``) and works faults-on or
    faults-off.

Everything stochastic (generated fault times, jitter, backing-fetch coin
flips) draws from a dedicated SeedSequence child of the engine seed that
is **only spawned when a plan is attached**, so fault-free runs keep the
golden-trace streams bit-for-bit, and one (seed, plan) pair always yields
the identical :class:`~repro.core.engine.EngineTrace` and
``fault_stats()``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

__all__ = [
    "CpuCrash", "DriveFailure", "DriveStall", "ExponentialBackoff",
    "FaultPlan", "FixedRetry", "NoRetry", "RepairModel", "RetryBudget",
    "RetryPolicy",
]

# internal timeline event kinds (time-ordered tuples the engine consumes)
DRIVE_FAIL, DRIVE_RECOVER, STALL_BEGIN, STALL_END, CPU_CRASH, CPU_RECOVER = \
    range(6)


# --------------------------------------------------------------------------
# explicit fault events
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class DriveFailure:
    """Fail-stop: drive ``drive`` dies at ``time``; with a finite
    ``down_s`` a replacement comes back (empty) that much later."""
    time: float
    drive: int
    down_s: float = math.inf


@dataclass(frozen=True)
class DriveStall:
    """Gray failure: services started on ``drive`` inside
    ``[time, time + duration_s)`` run ``factor`` x slower."""
    time: float
    drive: int
    duration_s: float
    factor: float = 8.0


@dataclass(frozen=True)
class CpuCrash:
    """CPU fallback node ``node`` dies at ``time`` for ``down_s``."""
    time: float
    node: int
    down_s: float = math.inf


# --------------------------------------------------------------------------
# retry policies
# --------------------------------------------------------------------------

class RetryPolicy:
    """Decides the re-dispatch delay of a lost request.

    ``delay_s(attempt, prev_delay_s, rng)`` returns the seconds to wait
    before attempt ``attempt`` (1-based count of losses so far), or
    ``None`` to give up.  ``prev_delay_s`` is the delay granted to this
    request's previous attempt (0.0 on the first), which is the state
    decorrelated jitter needs.
    """

    name = "base"
    max_attempts: int = 0

    def delay_s(self, attempt: int, prev_delay_s: float,
                rng: np.random.Generator) -> Optional[float]:
        raise NotImplementedError


class NoRetry(RetryPolicy):
    """Lost requests are never re-dispatched (abandoned)."""

    name = "none"

    def delay_s(self, attempt, prev_delay_s, rng):
        return None


@dataclass(frozen=True)
class FixedRetry(RetryPolicy):
    """Constant re-dispatch delay, up to ``max_attempts`` losses."""

    delay: float = 0.05
    max_attempts: int = 4
    name = "fixed"

    def delay_s(self, attempt, prev_delay_s, rng):
        if attempt > self.max_attempts:
            return None
        return self.delay


@dataclass(frozen=True)
class ExponentialBackoff(RetryPolicy):
    """Exponential backoff with decorrelated jitter.

    ``delay = min(cap_s, U(base_s, max(base_s, 3 * prev_delay)))`` — the
    expected delay grows geometrically with each loss while successive
    delays stay decorrelated across requests, so synchronized retry
    storms (every lost request hammering the repaired drive at once)
    cannot form.
    """

    base_s: float = 0.02
    cap_s: float = 2.0
    max_attempts: int = 6
    name = "exponential"

    def delay_s(self, attempt, prev_delay_s, rng):
        if attempt > self.max_attempts:
            return None
        hi = max(self.base_s, 3.0 * prev_delay_s)
        return min(self.cap_s, float(rng.uniform(self.base_s, hi))
                   if hi > self.base_s else self.base_s)


@dataclass(frozen=True)
class RetryBudget:
    """Fleet-wide retry circuit breaker (per run).

    Retries are granted while ``granted < min_tokens + ratio * arrivals``
    — i.e. the retry stream may never exceed ``ratio`` of the offered
    load (plus a small floor so early failures can still retry).  Beyond
    that the circuit opens and further losses are abandoned/degraded,
    which is what keeps a mass failure from doubling the offered load.
    """

    ratio: float = 0.25
    min_tokens: int = 16

    def allows(self, granted: int, arrivals: int) -> bool:
        return granted < self.min_tokens + self.ratio * arrivals


@dataclass(frozen=True)
class RepairModel:
    """Re-replication pipe: lost replicas are copied back onto surviving
    drives through one serialized stream of ``bandwidth_bps`` bytes/s
    (repairs queue behind each other, so a failure burst stretches the
    window during which objects sit under-replicated)."""

    bandwidth_bps: float = 200e6

    def validate(self) -> None:
        if self.bandwidth_bps <= 0.0:
            raise ValueError("repair bandwidth_bps must be positive")


# --------------------------------------------------------------------------
# the plan
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultPlan:
    """Everything the engine needs to inject faults and recover.

    ``events`` lists explicit faults; the ``*_mtbf_s`` knobs additionally
    generate per-server fault processes (exponential inter-fault gaps,
    drawn from the run's dedicated fault rng — deterministic per seed).
    ``drive_mttr_s``/``cpu_mttr_s`` of ``None`` mean fail-stop for the
    rest of the run.  ``retry``/``retry_budget`` govern re-dispatch of
    lost requests; ``repair`` attaches the re-replication pipe (needs the
    tiered data layer with a finite object universe);
    ``detect_timeout_s`` arms the per-dispatch stall watchdog;
    ``backing_fail_p``/``backing_retry_s`` make remote fetches fallible.
    """

    events: Tuple[object, ...] = ()
    drive_mtbf_s: Optional[float] = None
    drive_mttr_s: Optional[float] = None
    stall_mtbf_s: Optional[float] = None
    stall_s: float = 2.0
    stall_factor: float = 8.0
    cpu_mtbf_s: Optional[float] = None
    cpu_mttr_s: Optional[float] = None
    backing_fail_p: float = 0.0
    backing_retry_s: float = 0.03
    retry: RetryPolicy = field(default_factory=ExponentialBackoff)
    retry_budget: Optional[RetryBudget] = field(default_factory=RetryBudget)
    repair: Optional[RepairModel] = None
    detect_timeout_s: Optional[float] = None

    def validate(self) -> None:
        for ev in self.events:
            if not isinstance(ev, (DriveFailure, DriveStall, CpuCrash)):
                raise TypeError(f"unknown fault event: {ev!r}")
            if ev.time < 0.0:
                raise ValueError(f"fault event time must be >= 0: {ev!r}")
        for nm in ("drive_mtbf_s", "drive_mttr_s", "stall_mtbf_s",
                   "cpu_mtbf_s", "cpu_mttr_s"):
            v = getattr(self, nm)
            if v is not None and v <= 0.0:
                raise ValueError(f"{nm} must be positive")
        if self.stall_s <= 0.0 or self.stall_factor < 1.0:
            raise ValueError("stall_s must be positive and stall_factor "
                             ">= 1")
        if not 0.0 <= self.backing_fail_p < 1.0:
            raise ValueError("backing_fail_p must be in [0, 1)")
        if self.backing_retry_s < 0.0:
            raise ValueError("backing_retry_s must be >= 0")
        if not isinstance(self.retry, RetryPolicy):
            raise TypeError("retry must be a RetryPolicy")
        if self.repair is not None:
            self.repair.validate()
        if self.detect_timeout_s is not None and self.detect_timeout_s <= 0:
            raise ValueError("detect_timeout_s must be positive")

    # -- timeline expansion (deterministic from the fault rng) --------------
    def timeline(self, n_dscs: int, n_cpu: int, horizon_s: float,
                 rng: np.random.Generator) -> List[Tuple[float, int, int,
                                                         float]]:
        """Expand the plan into a sorted ``(time, kind, target, extra)``
        event list over ``[0, horizon_s)``.

        Generated processes draw exponential inter-fault gaps per server
        in index order, so the expansion is exactly reproducible from
        ``rng``; explicit events are merged in afterwards.  ``extra`` is
        the stall slowdown factor on ``STALL_BEGIN`` events and 0.0
        elsewhere.
        """
        out: List[Tuple[float, int, int, float]] = []

        def windows(mtbf: Optional[float], mttr: Optional[float], n: int,
                    k_begin: int, k_end: int, extra: float = 0.0,
                    width: Optional[float] = None) -> None:
            if mtbf is None or n <= 0 or horizon_s <= 0.0:
                return
            for srv in range(n):
                t = float(rng.exponential(mtbf))
                while t < horizon_s:
                    out.append((t, k_begin, srv, extra))
                    dur = width if width is not None else mttr
                    if dur is None:
                        break           # down for the rest of the run
                    out.append((t + dur, k_end, srv, 0.0))
                    t = t + dur + float(rng.exponential(mtbf))

        windows(self.drive_mtbf_s, self.drive_mttr_s, n_dscs,
                DRIVE_FAIL, DRIVE_RECOVER)
        windows(self.stall_mtbf_s, None, n_dscs, STALL_BEGIN, STALL_END,
                extra=self.stall_factor, width=self.stall_s)
        windows(self.cpu_mtbf_s, self.cpu_mttr_s, n_cpu,
                CPU_CRASH, CPU_RECOVER)

        for ev in self.events:
            if isinstance(ev, DriveFailure):
                if not 0 <= ev.drive < n_dscs:
                    raise ValueError(f"DriveFailure.drive {ev.drive} out of "
                                     f"range for {n_dscs} drives")
                out.append((ev.time, DRIVE_FAIL, ev.drive, 0.0))
                if math.isfinite(ev.down_s):
                    out.append((ev.time + ev.down_s, DRIVE_RECOVER,
                                ev.drive, 0.0))
            elif isinstance(ev, DriveStall):
                if not 0 <= ev.drive < n_dscs:
                    raise ValueError(f"DriveStall.drive {ev.drive} out of "
                                     f"range for {n_dscs} drives")
                out.append((ev.time, STALL_BEGIN, ev.drive, ev.factor))
                out.append((ev.time + ev.duration_s, STALL_END, ev.drive,
                            0.0))
            else:
                if not 0 <= ev.node < n_cpu:
                    raise ValueError(f"CpuCrash.node {ev.node} out of range "
                                     f"for {n_cpu} nodes")
                out.append((ev.time, CPU_CRASH, ev.node, 0.0))
                if math.isfinite(ev.down_s):
                    out.append((ev.time + ev.down_s, CPU_RECOVER, ev.node,
                                0.0))
        out.sort()
        return out


# --------------------------------------------------------------------------
# shard-local bookkeeping merge (sharded runs)
# --------------------------------------------------------------------------

def merge_fault_stats(states: List[Optional[dict]],
                      offered: int) -> Optional[dict]:
    """Merge per-shard ``fault_stats()`` dicts into one fleet view.

    Each shard of a sharded run injects faults and repairs replicas over
    its *own* drive/CPU slice from its own seed child; this folds those
    shard-local books back into the single-engine schema: counters sum,
    per-drive unavailability concatenates in shard (= drive) order, and
    the goodput fraction is recomputed against the fleet-wide ``offered``
    total.  Returns ``None`` when no shard tracked faults or deadlines.
    """
    live = [s for s in states if s is not None]
    if not live:
        return None
    completed = sum(s["goodput"]["completed"] for s in live)
    goodput = {"offered": offered, "completed": completed,
               "goodput_frac": completed / offered if offered else 0.0}
    dead = sum(s["deadline_abandoned"] for s in live)
    rejected = sum(s.get("rejected", 0) for s in live)
    shed = sum(s.get("shed", 0) for s in live)
    full = [s for s in live if s["enabled"]]
    if not full:
        return {"enabled": False, "abandoned": 0,
                "deadline_abandoned": dead, "rejected": rejected,
                "shed": shed, "goodput": goodput}
    per_drive: List[float] = []
    for s in live:
        per_drive += s["unavailability"]["per_drive_s"] if s["enabled"] else []
    out = {
        "enabled": True,
        "injected": {k: sum(s["injected"][k] for s in full)
                     for k in full[0]["injected"]},
        "lost": sum(s["lost"] for s in full),
        "retries": {k: sum(s["retries"][k] for s in full)
                    for k in full[0]["retries"]},
        "abandoned": sum(s["abandoned"] for s in full),
        "deadline_abandoned": dead,
        "rejected": rejected,
        "shed": shed,
        "degraded": sum(s["degraded"] for s in full),
        "detect_hedges": sum(s["detect_hedges"] for s in full),
        "unavailability": {"per_drive_s": per_drive,
                           "total_s": sum(per_drive)},
        "repair": {k: sum(s["repair"][k] for s in full)
                   for k in full[0]["repair"]},
        "goodput": goodput,
    }
    return out


__all__.append("merge_fault_stats")
