"""Design-space exploration for the near-storage DSA (§IV-B, Fig. 7).

Sweeps PE-array X/Y (4..1024, power-of-2), scratchpad (128 KB..32 MB) and
memory technology (DDR4 / DDR5 / HBM2) — 729 configurations (> the paper's
650) — evaluates average throughput over the Table I benchmark suite with
the tile model, and extracts the power<->performance and
area<->performance Pareto frontiers under the CSD power cap.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.core.dsa import (DSAConfig, dsa_area_mm2, dsa_power_w,
                            network_latency_s)
from repro.core.workloads import WORKLOADS, Workload

PE_SWEEP = (4, 8, 16, 32, 64, 128, 256, 512, 1024)
SPAD_SWEEP = tuple((128 << 10) * (1 << i) for i in range(9))   # 128KB..32MB
MEMBW_SWEEP = (19.2e9, 38e9, 460e9)                            # DDR4/DDR5/HBM2
PCIE_SLOT_CAP_W = 25.0          # PCIe slot budget (upper bound)
CSD_POWER_CAP_W = 18.0          # SmartSSD-class drive TDP
FLASH_POWER_W = 7.0             # reserved for the flash subsystem
DSA_POWER_CAP_W = CSD_POWER_CAP_W - FLASH_POWER_W


@dataclass(frozen=True)
class DSEPoint:
    cfg: DSAConfig
    throughput_fps: float        # average over the benchmark suite
    power_w: float
    area_mm2: float

    @property
    def feasible(self) -> bool:
        return self.power_w <= DSA_POWER_CAP_W


def evaluate(cfg: DSAConfig, workloads: Sequence[Workload] = None) -> DSEPoint:
    wls = list(workloads or WORKLOADS.values())
    lats = [max(network_latency_s(cfg, wl.gemms), 1e-7) for wl in wls]
    fps = len(lats) / sum(lats)  # harmonic-mean throughput (frames/s)
    return DSEPoint(cfg, fps, dsa_power_w(cfg), dsa_area_mm2(cfg))


def sweep(scratch_cap: int = 32 << 20) -> List[DSEPoint]:
    pts = []
    for px in PE_SWEEP:
        for py in PE_SWEEP:
            for bw in MEMBW_SWEEP:
                # scratchpad scaled with the array, capped (paper: large
                # scratchpads blow the power budget)
                spad = min(scratch_cap,
                           max(128 << 10, px * py * 256))
                pts.append(evaluate(DSAConfig(
                    pe_x=px, pe_y=py, scratchpad_bytes=spad, mem_bw=bw)))
    # plus explicit scratchpad sweep at the square design points
    for pe in PE_SWEEP:
        for spad in SPAD_SWEEP:
            for bw in MEMBW_SWEEP:
                pts.append(evaluate(DSAConfig(
                    pe_x=pe, pe_y=pe, scratchpad_bytes=spad, mem_bw=bw)))
    return pts


def pareto(points: Sequence[DSEPoint], x_attr: str) -> List[DSEPoint]:
    """Non-dominated set: minimize x_attr, maximize throughput."""
    pts = sorted(points, key=lambda p: (getattr(p, x_attr), -p.throughput_fps))
    front: List[DSEPoint] = []
    best = -math.inf
    for p in pts:
        if p.throughput_fps > best:
            front.append(p)
            best = p.throughput_fps
    return front


def optimal_design(points: Sequence[DSEPoint] = None) -> DSEPoint:
    """Highest-throughput feasible point on the power Pareto frontier."""
    pts = [p for p in (points or sweep()) if p.feasible]
    front = pareto(pts, "power_w")
    return max(front, key=lambda p: p.throughput_fps)


def optimal_square_design(points: Sequence[DSEPoint] = None) -> DSEPoint:
    """Best feasible SQUARE array — the paper's TPUv1-scaled search space."""
    pts = [p for p in (points or sweep())
           if p.feasible and p.cfg.pe_x == p.cfg.pe_y]
    return max(pts, key=lambda p: p.throughput_fps)
