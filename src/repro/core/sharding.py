"""Sharded fleet execution: partition the drive fleet across workers.

The classic engine (:meth:`ClusterEngine.run_soa`) is one event loop over
the whole fleet, which caps fleet-scale studies around ~10^5 req/s of
simulated throughput.  This module shards a run **by drive partition**:

* Each shard owns a contiguous, disjoint drive range plus a slice of the
  CPU fallback pool weighted by its drive share (every shard keeps at
  least one CPU node).  :class:`ShardPlan` pins the partition.
* Arrivals are split by the data-placement hash: request ``i`` belongs to
  the shard owning drive ``_placement(n_dscs, i)`` — the same memoized
  SHA-1 spread the classic engine dispatches on, so the per-request
  ``drive`` column is identical to the classic engine's.
* CPU copies (non-acceleratable requests and hedge fallbacks) are routed
  by a second consistent hash into the CPU block *derived from the
  request's drive*, so almost all CPU traffic stays shard-local; copies
  whose node lands in another shard's slice cross through a **bounded
  mailbox drained at epoch boundaries** (:class:`ShardMailbox`), counted
  in telemetry as ``shard_cpu_spillover`` / ``shard_cross_hedges``.
* Per-shard :class:`numpy.random.SeedSequence` children (spawned at
  stable indices ``4 + shard``) keep every shard bit-reproducible; the
  arrival stream and the pipeline-pick stream come from the same children
  (0, 1) the classic engine uses, so sharded runs simulate the same
  arrivals and the same accelerate/fallback mix.

Two execution paths, selected automatically:

**Partitioned fast path** (single-tenant, fault-free, tier-off, no
timeout): service times are materialized *per request* from the engine's
quantile-inversion transform (child 1, the classic pick/service stream),
and each shard solves its drives' FCFS queues with a vectorized Lindley
recursion; hedged CPU copies race per-node FCFS queues the same way.
Results are **independent of the shard count and of the process count**
— ``n_shards=2`` and ``n_shards=8``, serial or multiprocess, produce
byte-identical traces and telemetry — which is the property the
differential harness in ``tests/test_sharding.py`` gates.  Documented
deltas versus the classic event loop (which consumes service draws in
global event order and routes CPU copies to the least-loaded node):
per-request draws, consistent-hash CPU routing, and hedge losers running
to completion without queue-tombstone feedback.  On a single drive with
no hedging the two models coincide draw-for-draw.

**Shard-isolated fallback** (faults, tiering, a deadline, or overload
control): each shard
runs the full classic event loop on its own sub-fleet — tier replica
sets are built shard-local over the shard's drives and fault timelines
are drawn from the shard's own seed child, so no routing ever crosses a
shard boundary.  Aggregate conservation (``arrivals == completed +
abandoned``) and per-class busy-second caps hold exactly; per-request
timings are defined by the shard-local dynamics.

``ClusterEngine.run_sharded(n_shards=1)`` bypasses all of this and runs
the classic loop — byte-for-byte the golden-trace stream.
"""
from __future__ import annotations

import math
import multiprocessing as mp
import os
import warnings
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import lindley
from repro.core.faults import merge_fault_stats
from repro.core.function import Pipeline, is_acceleratable
from repro.core.overload import TokenBucket, merge_overload_stats
from repro.core.platforms import CPU_FALLBACK_PLATFORM, DSCS_PLATFORM
from repro.core.tiering import merge_tier_stats

__all__ = ["MailboxOverflow", "ShardMailbox", "ShardPlan", "cpu_affinity",
           "run_partitioned"]


# -- partition plan ----------------------------------------------------------
@dataclass(frozen=True)
class ShardPlan:
    """A drive/CPU partition of the fleet plus per-shard seeds.

    ``drive_bounds``/``cpu_bounds`` are ``n_shards + 1`` fenceposts:
    shard ``s`` owns drives ``[drive_bounds[s], drive_bounds[s+1])`` and
    CPU nodes ``[cpu_bounds[s], cpu_bounds[s+1])``.  The CPU slice is
    weighted by the shard's drive share and never empty.  ``shard_seeds``
    are derived from stable SeedSequence children ``4 + s`` of the engine
    seed (children 0–3 are the classic engine's arrival / pick-service /
    tier / fault streams), so adding shards never perturbs the streams
    any other component draws.
    """
    n_dscs: int
    n_cpu: int
    n_shards: int
    seed: int
    drive_bounds: Tuple[int, ...]
    cpu_bounds: Tuple[int, ...]
    shard_seeds: Tuple[int, ...]

    @classmethod
    def build(cls, n_dscs: int, n_cpu: int, n_shards: int,
              seed: int) -> "ShardPlan":
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if n_shards > n_dscs:
            raise ValueError(f"n_shards={n_shards} exceeds n_dscs={n_dscs}: "
                             "every shard needs at least one drive")
        if n_shards > n_cpu:
            raise ValueError(f"n_shards={n_shards} exceeds n_cpu={n_cpu}: "
                             "every shard needs at least one CPU node")
        k = n_shards
        db = [(s * n_dscs) // k for s in range(k + 1)]
        # CPU fenceposts track the drive share, then a monotone fix-up
        # guarantees >= 1 node per shard (k <= n_cpu makes this feasible)
        cb = [(db[s] * n_cpu) // n_dscs for s in range(k + 1)]
        cb[k] = n_cpu
        for s in range(1, k + 1):
            if cb[s] <= cb[s - 1]:
                cb[s] = cb[s - 1] + 1
        for s in range(k - 1, 0, -1):
            if cb[s] > n_cpu - (k - s):
                cb[s] = n_cpu - (k - s)
        kids = np.random.SeedSequence(seed).spawn(4 + k)[4:]
        seeds = tuple(int(c.generate_state(1, np.uint64)[0]) for c in kids)
        return cls(n_dscs=n_dscs, n_cpu=n_cpu, n_shards=k, seed=seed,
                   drive_bounds=tuple(db), cpu_bounds=tuple(cb),
                   shard_seeds=seeds)

    def shard_of_drive(self, drives: np.ndarray) -> np.ndarray:
        """Owning shard id for each drive index (vectorized)."""
        return (np.searchsorted(np.asarray(self.drive_bounds), drives,
                                side="right") - 1).astype(np.int32)

    def shard_of_cpu(self, nodes: np.ndarray) -> np.ndarray:
        """Owning shard id for each CPU node index (vectorized)."""
        return (np.searchsorted(np.asarray(self.cpu_bounds), nodes,
                                side="right") - 1).astype(np.int32)


# -- consistent-hash CPU routing ---------------------------------------------
# Vectorized splitmix64 finalizer over the request id: a fixed
# deterministic map (never reseeded), so the routed node is
# k-independent and the per-node CPU queues decompose the same way the
# per-drive queues do.  Unlike the placement table this hash is private
# to the sharded path, so it can use a numpy-wide mixer instead of the
# per-request SHA-1 the placement cache pays.
def _cpu_hash(n: int) -> np.ndarray:
    z = (np.arange(n, dtype=np.uint64)
         + np.uint64(0x9E3779B97F4A7C15)) * np.uint64(0xBF58476D1CE4E5B9)
    z ^= z >> np.uint64(27)
    z *= np.uint64(0x94D049BB133111EB)
    z ^= z >> np.uint64(31)
    return z


def cpu_affinity(n_dscs: int, n_cpu: int, n: int) -> np.ndarray:
    """Per-request CPU fallback node: a consistent hash into the CPU
    block derived from the request's placement drive.

    Drive ``d`` maps to nodes ``[d*nc//nd, (d+1)*nc//nd)`` (or the single
    node ``min(nc-1, d*nc//nd)`` when the fleet has more drives than CPU
    nodes), so CPU traffic stays near its shard; the result depends only
    on ``(n_dscs, n_cpu, i)``, never on the shard count.
    """
    from repro.core.engine import _placement
    d = _placement(n_dscs, n).astype(np.int64)
    lo = (d * n_cpu) // n_dscs
    hi = ((d + 1) * n_cpu) // n_dscs
    width = np.maximum(hi - lo, 1)
    np.minimum(lo, n_cpu - 1, out=lo)
    return (lo + (_cpu_hash(n) % width.astype(np.uint64)).astype(np.int64)
            ).astype(np.int32)


# -- bounded epoch mailbox ---------------------------------------------------
class MailboxOverflow(RuntimeError):
    """Raised when outstanding cross-phase messages exceed the mailbox
    capacity before the destination shard drains its epoch buckets."""


class ShardMailbox:
    """Bounded per-destination mailbox, drained at epoch boundaries.

    Shards never share queues directly: the drive phase posts CPU-copy
    batches ``(rids, dispatch_t, node)`` keyed by ``(dst_shard, epoch)``,
    and the CPU phase drains its buckets in epoch order before solving
    its node queues.  ``capacity`` bounds the total outstanding messages
    (posted, not yet drained); exceeding it raises
    :class:`MailboxOverflow`.  Counters: ``posted`` (messages routed),
    ``cross_shard`` (messages whose source and destination differ),
    ``max_outstanding`` (high-water mark).
    """

    def __init__(self, n_shards: int, capacity: int):
        self.capacity = int(capacity)
        self._box: List[Dict[int, list]] = [{} for _ in range(n_shards)]
        self.posted = 0
        self.cross_shard = 0
        self.outstanding = 0
        self.max_outstanding = 0

    def post(self, src: int, dst: int, epoch: int, rids: np.ndarray,
             disp: np.ndarray, node: np.ndarray) -> None:
        m = int(rids.size)
        if not m:
            return
        self.posted += m
        self.outstanding += m
        if self.outstanding > self.max_outstanding:
            self.max_outstanding = self.outstanding
        if self.outstanding > self.capacity:
            raise MailboxOverflow(
                f"{self.outstanding} outstanding messages exceed the "
                f"mailbox capacity {self.capacity}; raise "
                f"mailbox_capacity= or epoch_count=")
        if src != dst:
            self.cross_shard += m
        self._box[dst].setdefault(epoch, []).append((rids, disp, node))

    def drain(self, dst: int) -> List[Tuple[np.ndarray, np.ndarray,
                                            np.ndarray]]:
        """All batches destined to ``dst``, concatenated per epoch, in
        epoch order; the buckets are emptied."""
        box = self._box[dst]
        out = []
        for ep in sorted(box):
            batches = box.pop(ep)
            rids = np.concatenate([b[0] for b in batches])
            disp = np.concatenate([b[1] for b in batches])
            node = np.concatenate([b[2] for b in batches])
            self.outstanding -= int(rids.size)
            out.append((rids, disp, node))
        return out


# -- per-request tables (the partitioned fast path's sampling) ---------------
def _erfinv_vec(x: np.ndarray) -> np.ndarray:
    a = 0.147
    ln = np.log(1.0 - x * x)
    t = 2.0 / (math.pi * a) + ln / 2.0
    return np.copysign(np.sqrt(np.sqrt(t * t - ln / a) - t), x)


def _build_tables(engine, pipelines: Sequence[Pipeline],
                  times: np.ndarray) -> dict:
    """Materialize the per-request columns every shard slices.

    Picks come from SeedSequence child 1 exactly like the classic engine
    (same stream, same values), then the *same* generator supplies 2n
    uniform draws through the sampler's erfinv/lognormal transform:
    positions ``[0, n)`` are the DSCS-copy tails, ``[n, 2n)`` the
    CPU-copy tails.  The classic engine consumes the identical stream in
    event order instead of request order — on a single drive with no
    hedging the orders coincide and the service columns are bit-equal.

    The uniform stream is consumed in bounded chunks (sequential
    ``Generator.uniform`` calls concatenate to the same stream as one
    call, pinned by a test) so the erfinv/exp temporaries never
    materialize at full 2n length — at 10^7 requests that alone drops
    ~0.6 GB of transient peak.
    """
    n = int(times.size)
    nd, nc = engine.n_dscs, engine.n_cpu
    rng = np.random.default_rng(np.random.SeedSequence(engine.seed).spawn(2)[1])
    picks = (rng.integers(len(pipelines), size=n) if n
             else np.empty(0, dtype=np.int64))
    sampler = engine._sampler
    coef_d = np.array([sampler.coef(p.workload, DSCS_PLATFORM)
                       for p in pipelines])
    coef_c = np.array([sampler.coef(p.workload, CPU_FALLBACK_PLATFORM)
                       for p in pipelines])
    rs, ws = engine.lm.params.read_sigma, engine.lm.params.write_sigma
    chunk = 1 << 20

    def _service(coef: np.ndarray) -> np.ndarray:
        # consumes the next n uniforms; element-wise math is unchanged,
        # so chunking is invisible to the output bits
        out = np.empty(n)
        for a in range(0, n, chunk):
            u = rng.uniform(size=min(chunk, n - a))
            np.clip(u, 1e-4, 1.0 - 1e-4, out=u)
            z = math.sqrt(2.0) * _erfinv_vec(2.0 * u - 1.0)
            pk = picks[a:a + u.size]
            out[a:a + u.size] = (coef[pk, 0] + coef[pk, 1] * np.exp(rs * z)
                                 + coef[pk, 2] * np.exp(ws * z))
        return out

    svc_d = _service(coef_d)
    svc_c = _service(coef_c)
    accel_pipe = np.array([nd > 0 and is_acceleratable(p) for p in pipelines],
                          dtype=bool)
    from repro.core.engine import _placement
    accel = accel_pipe[picks] if n else np.empty(0, dtype=bool)
    drive = (_placement(nd, n).astype(np.int64) if n
             else np.empty(0, dtype=np.int64))
    # drive-sorted orders, computed once: each shard slices its own
    # contiguous block with two binary searches instead of scanning and
    # re-sorting the full request stream
    acc_idx = np.flatnonzero(accel)
    acc_order = acc_idx[np.argsort(drive[acc_idx], kind="stable")]
    na_idx = np.flatnonzero(~accel)
    na_order = na_idx[np.argsort(drive[na_idx], kind="stable")]
    return {"picks": picks, "svc_d": svc_d, "svc_c": svc_c,
            "accel": accel, "drive": drive, "cnode": cpu_affinity(nd, nc, n),
            "acc_order": acc_order, "acc_drive": drive[acc_order],
            "na_order": na_order, "na_drive": drive[na_order]}


# -- vectorized FCFS (Lindley recursion) -------------------------------------
def _fcfs_segment(t: np.ndarray, s: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Service start/finish for one FCFS single-server queue: arrivals
    ``t`` (sorted), service demands ``s``.  ``f_j = max_{i<=j}(t_i +
    sum(s_i..s_j))`` via cumsum + running max; the start is clamped to
    the arrival so idle starts are exact."""
    c = np.cumsum(s)
    prev = c - s
    m = np.maximum.accumulate(t - prev)
    start = np.maximum(t, m + prev)
    return start, start + s


def _queue_depth_max(start: np.ndarray, t: np.ndarray) -> int:
    """Max queued-copy depth of one FCFS queue, sampled at arrivals
    (depth only grows at an arrival).  The classic engine pins max_depth
    >= 1 whenever the server dispatched at all."""
    m = int(t.size)
    if not m:
        return 0
    depth = np.arange(1, m + 1) - np.searchsorted(start, t, side="right")
    return max(int(depth.max()), 1)


def _grouped_fcfs(keys: np.ndarray, lo: int, hi: int, t: np.ndarray,
                  s: np.ndarray, start: np.ndarray, fin: np.ndarray,
                  backend: str = "segmented"
                  ) -> Tuple[List[float], List[float], List[int]]:
    """Solve every server's FCFS queue for rows sorted by ``keys``
    (server ids in ``[lo, hi)``): `_fcfs_segment` batched over all
    servers at once through :mod:`repro.core.lindley` (length-bucketed
    segmented scan by default; ``backend`` selects the Pallas kernel or
    the legacy padded-dense layout — all bit-identical).  Fills
    ``start``/``fin`` in place and returns per-server (busy_s,
    queue-area, max-depth) lists."""
    nserv = hi - lo
    if not t.size:
        return [0.0] * nserv, [0.0] * nserv, [0] * nserv
    seg = lindley.segment_fenceposts(keys, lo, hi)
    lindley.solve_segments(seg, t, s, start, fin, backend=backend)
    lens = np.diff(seg)
    rows = np.repeat(np.arange(nserv), lens)
    busy = np.bincount(rows, weights=s, minlength=nserv).tolist()
    area = np.bincount(rows, weights=start - t, minlength=nserv).tolist()
    maxd = lindley.queue_depth_max(seg, start, t)
    return busy, area, maxd


# -- fork-shared worker state ------------------------------------------------
# Workers are forked (Linux): the parent stashes the read-only tables
# here *before* creating the pool, so children see them copy-on-write
# and only the per-shard results travel back through pickling.
_FORK_STATE: Optional[dict] = None


def _iter_shards(fn, items, processes: int):
    """Yield ``fn(item)`` results in item order, lazily.

    Serial execution runs one shard at a time; the fork pool streams
    results back via ``imap`` (order-preserving).  Either way the caller
    can merge-and-free each shard's arrays while later shards are still
    being solved, so parent peak RSS holds one shard's result set, not
    the whole run's.
    """
    if processes <= 1:
        for x in items:
            yield fn(x)
        return
    ctx = mp.get_context("fork")
    with ctx.Pool(min(processes, len(items))) as pool:
        for res in pool.imap(fn, items):
            yield res


def _map_shards(fn, items, processes: int):
    return list(_iter_shards(fn, items, processes))


# -- partitioned fast path ---------------------------------------------------
def _drive_phase(s: int) -> dict:
    st = _FORK_STATE
    plan: ShardPlan = st["plan"]
    lo, hi = plan.drive_bounds[s], plan.drive_bounds[s + 1]
    times, svc_d = st["times"], st["tab"]["svc_d"]
    cnode = st["tab"]["cnode"]
    hedge = st["hedge"]

    a0, a1 = np.searchsorted(st["tab"]["acc_drive"], [lo, hi])
    order = st["tab"]["acc_order"][a0:a1]
    t = times[order]
    sv = svc_d[order]
    start = np.empty_like(t)
    fin = np.empty_like(t)
    busy, area, maxd = _grouped_fcfs(st["tab"]["acc_drive"][a0:a1], lo, hi,
                                     t, sv, start, fin,
                                     backend=st["backend"])

    # hedge decisions are a pure function of the drive-side wait (the
    # classic engine fires the hedge timer when the copy is still queued
    # at t + budget; timers win ties against finish events, hence >=)
    if hedge is not None and order.size:
        hm = (start - t) >= hedge
        h_rids = order[hm]
        h_disp = t[hm] + hedge
    else:
        h_rids = np.empty(0, dtype=np.int64)
        h_disp = np.empty(0, dtype=np.float64)

    n0, n1 = np.searchsorted(st["tab"]["na_drive"], [lo, hi])
    na = st["tab"]["na_order"][n0:n1]
    c_rids = np.concatenate([na, h_rids])
    c_disp = np.concatenate([times[na], h_disp])
    c_node = cnode[c_rids]

    # batch outgoing CPU copies by (destination shard, epoch)
    batches = []
    if c_rids.size:
        dest = plan.shard_of_cpu(c_node)
        epoch = np.minimum((c_disp / st["epoch_s"]).astype(np.int64),
                           st["epoch_count"] - 1)
        g = np.lexsort((epoch, dest))
        dest_g, epoch_g = dest[g], epoch[g]
        cut = np.flatnonzero(np.diff(dest_g) | np.diff(epoch_g))
        bounds = np.concatenate([[0], cut + 1, [dest_g.size]])
        for a, b in zip(bounds[:-1], bounds[1:]):
            sel = g[a:b]
            batches.append((int(dest_g[a]), int(epoch_g[a]), c_rids[sel],
                            c_disp[sel], c_node[sel]))
    return {"rids": order, "start": start, "fin": fin,
            "busy": busy, "area": area, "maxd": maxd,
            "n_accel": int(order.size), "n_hedged": int(h_rids.size),
            "n_nonaccel": int(na.size), "batches": batches}


def _cpu_phase(args) -> dict:
    s, inbox = args
    st = _FORK_STATE
    plan: ShardPlan = st["plan"]
    clo, chi = plan.cpu_bounds[s], plan.cpu_bounds[s + 1]
    svc_c = st["tab"]["svc_c"]
    if inbox:
        rids = np.concatenate([b[0] for b in inbox])
        disp = np.concatenate([b[1] for b in inbox])
        node = np.concatenate([b[2] for b in inbox])
    else:
        rids = np.empty(0, dtype=np.int64)
        disp = np.empty(0, dtype=np.float64)
        node = np.empty(0, dtype=np.int32)
    # one deterministic total order per node, independent of the epoch
    # batching (epochs bound the transport, not the math)
    g = np.lexsort((rids, disp, node))
    rids, disp, node = rids[g], disp[g], node[g]
    sv = svc_c[rids]
    start = np.empty_like(disp)
    fin = np.empty_like(disp)
    busy, area, maxd = _grouped_fcfs(node, clo, chi, disp, sv, start, fin,
                                     backend=st["backend"])
    return {"rids": rids, "start": start, "fin": fin, "node": node,
            "busy": busy, "area": area, "maxd": maxd}


def _run_partitioned_pure(engine, pipelines, times, plan: ShardPlan,
                          processes: int, epoch_count: int,
                          mailbox_capacity: Optional[int],
                          backend: str = "segmented"):
    from repro.core.engine import EngineTrace
    global _FORK_STATE
    n = int(times.size)
    nd, nc = engine.n_dscs, engine.n_cpu
    k = plan.n_shards
    tab = _build_tables(engine, pipelines, times)
    hedge = engine.hedge_budget_s
    horizon_est = float(times[-1]) + (hedge or 0.0) + 1e-9 if n else 1.0
    _FORK_STATE = {"plan": plan, "times": times, "tab": tab, "hedge": hedge,
                   "epoch_s": horizon_est / epoch_count,
                   "epoch_count": epoch_count, "backend": backend}

    # -- solve + streaming merge ---------------------------------------------
    # Each shard's result is merged into the full-length columns and
    # freed as soon as it lands (results arrive in shard order), so the
    # parent never holds every shard's arrays at once.
    nan = math.nan
    d_start = np.full(n, nan)
    d_fin = np.full(n, nan)
    c_start = np.full(n, nan)
    c_fin = np.full(n, nan)
    hedged = np.zeros(n, dtype=bool)
    d_busy_l: List[float] = []
    d_area_l: List[float] = []
    d_maxd_l: List[int] = []
    c_busy_l: List[float] = []
    c_area_l: List[float] = []
    c_maxd_l: List[int] = []
    n_hedged = 0
    mailbox = ShardMailbox(
        k, mailbox_capacity if mailbox_capacity is not None
        else max(65536, 2 * n))
    try:
        for s, res in enumerate(_iter_shards(_drive_phase, list(range(k)),
                                             processes)):
            d_start[res["rids"]] = res["start"]
            d_fin[res["rids"]] = res["fin"]
            d_busy_l += res["busy"]
            d_area_l += res["area"]
            d_maxd_l += res["maxd"]
            n_hedged += res["n_hedged"]
            for dst, ep, rids, disp, node in res["batches"]:
                mailbox.post(s, dst, ep, rids, disp, node)
        for res in _iter_shards(_cpu_phase,
                                [(s, mailbox.drain(s)) for s in range(k)],
                                processes):
            c_start[res["rids"]] = res["start"]
            c_fin[res["rids"]] = res["fin"]
            c_busy_l += res["busy"]
            c_area_l += res["area"]
            c_maxd_l += res["maxd"]
    finally:
        _FORK_STATE = None
    accel, drive = tab["accel"], tab["drive"]
    hedged[accel & ~np.isnan(c_fin)] = True

    # the winner is the first finisher; the classic heap pops the DSCS
    # finish first on exact ties, hence <=
    winner = np.where(accel, np.int8(0), np.int8(1))
    raced = hedged & (c_fin < d_fin)
    winner[raced] = 1
    dscs_won = winner == 0
    finish = np.where(dscs_won, d_fin, c_fin)
    start = np.where(dscs_won, d_start, c_start)
    service = np.where(dscs_won, tab["svc_d"], tab["svc_c"])
    end_t = 0.0
    if n:
        end_t = float(max(np.nanmax(d_fin) if accel.any() else 0.0,
                          np.nanmax(c_fin) if (~dscs_won | hedged).any()
                          else 0.0))
    n_accel = int(np.count_nonzero(accel))
    n_nonaccel = n - n_accel
    n_copies = n_accel + n_nonaccel + n_hedged
    events = n + n_copies + (n_accel if hedge is not None else 0)

    # -- telemetry / stats, mirroring the classic finalization ---------------
    inc = engine.telemetry.inc
    won_d = int(np.count_nonzero(hedged & dscs_won))
    won_c = int(np.count_nonzero(hedged & ~dscs_won))
    for name, v in (("dscs_dispatch", n_accel), ("cpu_dispatch", n_nonaccel),
                    ("hedge_issued", n_hedged), ("dscs_fallback", n_hedged),
                    ("hedge_won_dscs", won_d), ("hedge_won_cpu", won_c),
                    ("dscs_served", n_accel - n_hedged),
                    ("cpu_served", n_nonaccel),
                    ("shard_mailbox_msgs", mailbox.posted),
                    ("shard_cpu_spillover", mailbox.cross_shard)):
        if v:
            inc(name, v)
    engine._qstate = {"horizon": end_t,
                      "dscs": (d_area_l, d_maxd_l),
                      "cpu": (c_area_l, c_maxd_l),
                      "tombstones_discarded": 0, "cancelled_in_queue": 0}
    engine._pstate = {"horizon": end_t,
                      "dscs": {"busy_s": float(sum(d_busy_l)),
                               "powered_s": end_t * nd, "n": nd},
                      "cpu": {"busy_s": float(sum(c_busy_l)),
                              "powered_s": end_t * nc, "n": nc},
                      "wake_events": 0, "epochs": 0}
    engine._tstate = None
    engine._fstate = None
    engine._tierstate = None
    engine._ovstate = None
    engine.last_shard_stats = {
        "n_shards": k, "processes": processes,
        "mailbox": {"posted": mailbox.posted,
                    "cross_shard": mailbox.cross_shard,
                    "max_outstanding": mailbox.max_outstanding,
                    "capacity": mailbox.capacity},
        "cross_shard_hedges": _cross_shard_hedges(plan, tab, hedged),
        "path": "partitioned"}

    return EngineTrace(
        arrival=times, finish=finish, winner=winner,
        drive=np.where(dscs_won, drive, -1).astype(np.int32),
        start=start, service=service, hedged=hedged,
        dscs_finish=d_fin, cpu_finish=c_fin, events=events,
        tenant=np.zeros(n, dtype=np.int32))


def _cross_shard_hedges(plan: ShardPlan, tab: dict,
                        hedged: np.ndarray) -> int:
    """Hedged requests whose CPU copy landed in another shard's slice."""
    h = np.flatnonzero(hedged)
    if not h.size:
        return 0
    src = plan.shard_of_drive(tab["drive"][h])
    dst = plan.shard_of_cpu(tab["cnode"][h])
    return int(np.count_nonzero(src != dst))


# -- shard-isolated fallback (faults / tiering / deadlines) ------------------
def _fallback_worker(s: int) -> dict:
    st = _FORK_STATE
    from repro.core.engine import ClusterEngine
    plan: ShardPlan = st["plan"]
    lo, hi = plan.drive_bounds[s], plan.drive_bounds[s + 1]
    clo, chi = plan.cpu_bounds[s], plan.cpu_bounds[s + 1]
    rids = st["rids"][s]
    sub = ClusterEngine(
        n_dscs=hi - lo, n_cpu=chi - clo, latency_model=st["lm"],
        hedge_budget_s=st["hedge"], seed=plan.shard_seeds[s],
        n_plain=st["n_plain"], dscs_wake_s=st["dscs_wake_s"],
        preempt_losers=st["preempt_losers"], tier=st["tier"],
        faults=st["faults"], overload=st["overload"][s])
    tr = sub.run_soa(st["pipelines"], times=st["times"][rids],
                     timeout_s=st["timeout_s"])
    return {"trace": tr, "qstate": sub._qstate, "pstate": sub._pstate,
            "fstate": sub._fstate, "tierstate": sub._tierstate,
            "ovstate": sub._ovstate,
            "counters": dict(sub.telemetry.counters)}


def _shard_overload(ov, rids, n: int) -> list:
    """Per-shard overload configs for the isolated fallback: each shard
    runs its own control loop over its sub-fleet, so a fleet-wide
    :class:`TokenBucket` rate/burst is scaled by the shard's arrival
    share (depth-relative policies — thresholds, shedding, backpressure,
    brownout — carry over unchanged)."""
    if ov is None:
        return [None] * len(rids)
    out = []
    for ix in rids:
        adm = ov.admission
        if isinstance(adm, TokenBucket) and n:
            frac = len(ix) / n
            out.append(replace(ov, admission=replace(
                adm, rate=adm.rate * frac,
                burst=max(1.0, adm.burst * frac))))
        else:
            out.append(ov)
    return out


def _run_shard_isolated(engine, pipelines, times, plan: ShardPlan,
                        processes: int, timeout_s: Optional[float],
                        overload=None):
    from repro.core.engine import EngineTrace, _placement
    global _FORK_STATE
    n = int(times.size)
    k = plan.n_shards
    owner = plan.shard_of_drive(_placement(engine.n_dscs, n)) if n else \
        np.empty(0, dtype=np.int32)
    rids = [np.flatnonzero(owner == s) for s in range(k)]
    _FORK_STATE = {
        "plan": plan, "times": times, "rids": rids, "pipelines": pipelines,
        "lm": engine.lm, "hedge": engine.hedge_budget_s,
        "n_plain": engine.n_plain, "dscs_wake_s": engine.dscs_wake_s,
        "preempt_losers": engine.preempt_losers, "tier": engine.tier,
        "faults": engine.faults, "timeout_s": timeout_s,
        "overload": _shard_overload(overload, rids, n)}
    try:
        results = _map_shards(_fallback_worker, list(range(k)), processes)
    finally:
        _FORK_STATE = None

    nan = math.nan
    finish = np.full(n, nan)
    winner = np.full(n, -1, dtype=np.int8)
    drive = np.full(n, -1, dtype=np.int32)
    start = np.zeros(n)
    service = np.zeros(n)
    hedged = np.zeros(n, dtype=bool)
    d_fin = np.full(n, nan)
    c_fin = np.full(n, nan)
    events = 0
    d_area: List[float] = []
    d_maxd: List[int] = []
    c_area: List[float] = []
    c_maxd: List[int] = []
    horizon = 0.0
    d_busy = c_busy = d_pow = c_pow = 0.0
    wake = epochs = tomb = can_q = 0
    counters: Dict[str, float] = {}
    for s, res in enumerate(results):
        tr = res["trace"]
        ix = rids[s]
        finish[ix] = tr.finish
        winner[ix] = tr.winner
        drv = tr.drive.astype(np.int32)
        drive[ix] = np.where(drv >= 0, drv + plan.drive_bounds[s], -1)
        start[ix] = tr.start
        service[ix] = tr.service
        hedged[ix] = tr.hedged
        d_fin[ix] = tr.dscs_finish
        c_fin[ix] = tr.cpu_finish
        events += tr.events
        qs, ps = res["qstate"], res["pstate"]
        horizon = max(horizon, qs["horizon"])
        d_area += qs["dscs"][0]
        d_maxd += qs["dscs"][1]
        c_area += qs["cpu"][0]
        c_maxd += qs["cpu"][1]
        tomb += qs["tombstones_discarded"]
        can_q += qs["cancelled_in_queue"]
        d_busy += ps["dscs"]["busy_s"]
        d_pow += ps["dscs"]["powered_s"]
        c_busy += ps["cpu"]["busy_s"]
        c_pow += ps["cpu"]["powered_s"]
        wake += ps["wake_events"]
        epochs += ps["epochs"]
        for name, v in res["counters"].items():
            counters[name] = counters.get(name, 0.0) + v
    for name, v in counters.items():
        if v:
            engine.telemetry.inc(name, v)
    engine._qstate = {"horizon": horizon, "dscs": (d_area, d_maxd),
                      "cpu": (c_area, c_maxd),
                      "tombstones_discarded": tomb,
                      "cancelled_in_queue": can_q}
    engine._pstate = {"horizon": horizon,
                      "dscs": {"busy_s": d_busy, "powered_s": d_pow,
                               "n": engine.n_dscs},
                      "cpu": {"busy_s": c_busy, "powered_s": c_pow,
                              "n": engine.n_cpu},
                      "wake_events": wake, "epochs": epochs}
    engine._tstate = None
    engine._fstate = merge_fault_stats(
        [res["fstate"] for res in results], offered=n)
    engine._tierstate = merge_tier_stats(
        [res["tierstate"] for res in results])
    engine._ovstate = merge_overload_stats(
        [res["ovstate"] for res in results])
    engine.last_shard_stats = {"n_shards": k, "processes": processes,
                               "mailbox": None, "cross_shard_hedges": 0,
                               "path": "shard-isolated"}
    return EngineTrace(
        arrival=times, finish=finish, winner=winner, drive=drive,
        start=start, service=service, hedged=hedged,
        dscs_finish=d_fin, cpu_finish=c_fin, events=events,
        tenant=np.zeros(n, dtype=np.int32))


# -- entry point -------------------------------------------------------------
def run_partitioned(engine, pipelines: Optional[Sequence[Pipeline]], *,
                    arrivals=None, duration_s: float = 0.0,
                    times: Optional[np.ndarray] = None, n_shards: int,
                    processes: Optional[int] = None,
                    timeout_s: Optional[float] = None,
                    epoch_count: int = 64,
                    mailbox_capacity: Optional[int] = None,
                    backend: str = "segmented",
                    overload=None):
    """Execute one sharded run (``n_shards >= 2``); see the module
    docstring for the two paths.  Called via
    :meth:`ClusterEngine.run_sharded`.

    ``backend`` picks the Lindley solver on the partitioned fast path
    (:data:`repro.core.lindley.BACKENDS`: ``segmented``/``pallas``/
    ``dense`` — all bit-identical); the shard-isolated fallback runs the
    classic event loop and ignores it — a non-default ``backend`` on a
    fallback run raises a ``UserWarning`` so the Pallas/segmented knob
    never silently does nothing.

    ``overload`` (or the engine-level config) routes the run through the
    shard-isolated fallback; each shard runs its own control loop
    (fleet-wide :class:`TokenBucket` rates are scaled to the shard's
    arrival share) and the per-shard books merge through
    :func:`repro.core.overload.merge_overload_stats`.
    """
    if pipelines is None or not len(pipelines):
        raise ValueError("run_sharded needs a non-empty pipelines list "
                         "(tenants= is not supported sharded; run them "
                         "with n_shards=1)")
    if epoch_count < 1:
        raise ValueError("epoch_count must be >= 1")
    if backend not in lindley.BACKENDS:
        raise ValueError(f"backend must be one of {lindley.BACKENDS}, "
                         f"got {backend!r}")
    plan = ShardPlan.build(engine.n_dscs, engine.n_cpu, n_shards, engine.seed)
    if processes is None:
        processes = min(n_shards, os.cpu_count() or 1)

    if times is None:
        if arrivals is None:
            raise ValueError("pass arrivals= or times=")
        if duration_s <= 0.0:
            raise ValueError("arrivals= needs a positive duration_s")
        # child 0, exactly like the classic engine's arrival stream
        arr_rng = np.random.default_rng(
            np.random.SeedSequence(engine.seed).spawn(1)[0])
        times = arrivals.times(duration_s, arr_rng)
    times = np.ascontiguousarray(np.asarray(times, dtype=np.float64))

    tier_on = engine.tier is not None and engine.tier.enabled
    ov = overload if overload is not None else engine.overload
    ov_on = ov is not None and ov.enabled
    if engine.faults is not None or tier_on or timeout_s is not None \
            or ov_on:
        if backend != "segmented":
            warnings.warn(
                f"backend={backend!r} has no effect: faults/tiering/"
                "deadline/overload runs take the shard-isolated fallback "
                "(the classic event loop), not the Lindley fast path",
                UserWarning, stacklevel=3)
        return _run_shard_isolated(engine, pipelines, times, plan,
                                   processes, timeout_s,
                                   overload=ov if ov_on else None)
    return _run_partitioned_pure(engine, pipelines, times, plan, processes,
                                 epoch_count, mailbox_capacity, backend)
