"""Cost-efficiency model (§VI-A):

    CostEfficiency = Throughput x T / (CAPEX + OPEX)
    OPEX = sum(Power x T x Electricity)

CAPEX per platform from vendor list prices; the DSA's CAPEX follows the
ASIC-Clouds amortization (NRE spread over volume + silicon cost per mm^2 +
drive electronics).  T = 3 years, electricity $0.0733/kWh.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.dsa import DSAConfig, dsa_area_mm2
from repro.core.energy import pipeline_energy_j
from repro.core.latency import LatencyModel
from repro.core.platforms import Platform, PLATFORMS
from repro.core.workloads import Workload

ELECTRICITY_USD_PER_KWH = 0.0733
# re-replication traffic (replica repair after a drive failure or an
# autoscaler power-down): cross-rack bytes priced like cloud intra-region
# transfer — the autoscaling evaluation charges this per repaired GB so
# aggressive drive power-cycling pays for the repair traffic it causes
REPAIR_USD_PER_GB = 0.02
T_YEARS = 3.0
T_SECONDS = T_YEARS * 365.25 * 24 * 3600
HOST_SHARE_USD = 7500.0          # shared node/server infrastructure

# ASIC-Clouds-style: NRE / volume + wafer cost per mm^2 at 14 nm
NRE_USD = 8e6
VOLUME = 1e5
SILICON_USD_PER_MM2 = 0.10
DRIVE_USD = 320.0                # the SSD itself


DRIVES_PER_STORAGE_NODE = 16     # chassis share amortized across its drives


def dsa_capex_usd(cfg: DSAConfig = DSAConfig()) -> float:
    return (NRE_USD / VOLUME + dsa_area_mm2(cfg) * SILICON_USD_PER_MM2
            + DRIVE_USD + 120.0)  # + board/controller


def rental_rate_usd_per_s(plat: Platform, *, dsa_cfg=None) -> float:
    """Amortized CAPEX of keeping one node provisioned, in $/s over the
    3-year window (cloud-rental style: a powered-down server stops
    accruing).  Electricity is OPEX and accounted separately from metered
    energy.  CPU/GPU nodes carry the full ``HOST_SHARE_USD``; a DSCS drive
    carries 1/``DRIVES_PER_STORAGE_NODE`` of it (many drives share one
    storage chassis) on top of its ASIC-Clouds-amortized silicon.

    This is what the autoscaling evaluation (:mod:`repro.core.autoscale`)
    multiplies by powered server-seconds to price a fleet policy.
    """
    if plat.kind == "dsa":
        capex = (dsa_capex_usd(dsa_cfg or DSAConfig())
                 + HOST_SHARE_USD / DRIVES_PER_STORAGE_NODE)
    else:
        capex = plat.price_usd + HOST_SHARE_USD
    return capex / T_SECONDS


def cost_efficiency(lm: LatencyModel, plat: Platform, wl: Workload, *,
                    batch: int = 1, dsa_cfg=None) -> float:
    """Requests per dollar over the 3-year window."""
    lat = lm.e2e(plat, wl, batch=batch, dsa_cfg=dsa_cfg)
    thr = batch / lat                                   # req/s (run-to-completion)
    energy = pipeline_energy_j(lm, plat, wl, batch=batch, dsa_cfg=dsa_cfg)
    avg_power = energy["total"] / lat
    capex = (dsa_capex_usd(dsa_cfg or DSAConfig())
             if plat.kind == "dsa" else plat.price_usd) + HOST_SHARE_USD
    opex = avg_power * T_SECONDS / 3600.0 / 1000.0 * ELECTRICITY_USD_PER_KWH
    return thr * T_SECONDS / (capex + opex)


def cost_efficiency_vs_baseline(lm: LatencyModel, wl: Workload,
                                plat_name: str, **kw) -> float:
    return (cost_efficiency(lm, PLATFORMS[plat_name], wl, **kw)
            / cost_efficiency(lm, PLATFORMS["Baseline-CPU"], wl, **kw))
