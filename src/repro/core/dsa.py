"""Analytical tile-level performance model of the DSA (§IV + §VI-A).

Plays the role of the paper's cycle-accurate simulator (which they validated
to <=10% against the SmartSSD FPGA build of the same RTL): a weight-
stationary systolic array executes a network as a sequence of tiled GEMMs;
per (bm, bk, bn) tile the compiler double-buffers the next tile's DMA
against the current tile's compute, so per-tile latency is
max(compute_cycles, dma_cycles) — exactly the overlap argument the paper
uses to explain why 1024x1024 arrays LOSE to 128x128 at batch 1 (huge tiles
make DMA dominate and the pipeline stall).

The same model drives the DSE (core/dse.py) and the end-to-end latency
model (core/latency.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple


@dataclass(frozen=True)
class DSAConfig:
    pe_x: int = 128
    pe_y: int = 128
    scratchpad_bytes: int = 4 << 20
    mem_bw: float = 38e9          # DDR5
    freq_hz: float = 1e9
    dtype_bytes: int = 1          # int8 datapath (TPUv1-style)

    @property
    def name(self) -> str:
        return (f"{self.pe_x}x{self.pe_y}/"
                f"{self.scratchpad_bytes >> 20}MB/{self.mem_bw / 1e9:.0f}GBs")


@dataclass(frozen=True)
class GemmShape:
    """One layer lowered to GEMM (convs via im2col)."""
    m: int      # output rows (batch * output pixels)
    k: int      # reduction
    n: int      # output channels
    vector_ops: int = 0   # trailing vector-engine work (activation etc.)


def tile_dims(cfg: DSAConfig, g: GemmShape) -> Tuple[int, int, int]:
    """Pick (bm, bk, bn): array-aligned K/N, M sized so weights tile,
    activation tile and the fp32 partial-sum accumulators all fit the
    double-buffered scratchpad."""
    bk = min(g.k, cfg.pe_x)
    bn = min(g.n, cfg.pe_y)
    budget = cfg.scratchpad_bytes // 2          # double-buffered halves
    w_bytes = bk * bn * cfg.dtype_bytes
    # per activation row: input (bk) at datapath width + fp32 accumulator (bn)
    per_row = bk * cfg.dtype_bytes + bn * 4
    bm = max(1, min(g.m, (budget - w_bytes) // max(per_row, 1)))
    return bm, bk, bn


def gemm_cycles(cfg: DSAConfig, g: GemmShape) -> Tuple[float, float, float]:
    """Returns (total_cycles, compute_cycles, dma_cycles)."""
    bm, bk, bn = tile_dims(cfg, g)
    n_m = math.ceil(g.m / bm)
    n_k = math.ceil(g.k / bk)
    n_n = math.ceil(g.n / bn)
    tiles = n_m * n_k * n_n
    # systolic, weight-stationary: per tile, weights are preloaded down the
    # array (pe_x cycles) and bm activation rows stream through; the fill/
    # drain latency scales with the PHYSICAL array dims, not the tile dims —
    # this is why batch-1 tiles on a 1024x1024 array stall (Fig. 7 text)
    comp_tile = bm + cfg.pe_x + cfg.pe_y - 2
    bytes_tile = (bk * bn + bm * bk) * cfg.dtype_bytes     # weights + acts
    dma_tile = bytes_tile * cfg.freq_hz / cfg.mem_bw       # cycles
    per_tile = max(comp_tile, dma_tile)                    # double-buffered
    fill = comp_tile + dma_tile                            # pipeline prologue
    out_bytes = g.m * g.n * cfg.dtype_bytes
    drain = out_bytes * cfg.freq_hz / cfg.mem_bw
    total = tiles * per_tile + fill + drain + g.vector_ops / (8 * 128)
    return total, tiles * comp_tile, tiles * dma_tile


def network_latency_s(cfg: DSAConfig, gemms: Sequence[GemmShape]) -> float:
    return sum(gemm_cycles(cfg, g)[0] for g in gemms) / cfg.freq_hz


def network_flops(gemms: Sequence[GemmShape]) -> float:
    return sum(2.0 * g.m * g.k * g.n for g in gemms)


def utilization(cfg: DSAConfig, gemms: Sequence[GemmShape]) -> float:
    fl = network_flops(gemms)
    t = network_latency_s(cfg, gemms)
    peak = 2.0 * cfg.pe_x * cfg.pe_y * cfg.freq_hz
    return fl / (t * peak) if t > 0 else 0.0


# --- power / area model (45 nm synthesis -> scaled) --------------------------
# Per-PE numbers in the ballpark of the paper's Synopsys DC / FreePDK45
# synthesis at 1 GHz; SRAM numbers CACTI-P-like.
PE_POWER_45NM_W = 6.3e-4         # dynamic+leakage per int8 MAC PE at 1 GHz
PE_AREA_45NM_MM2 = 2.6e-3
SRAM_POWER_45NM_W_PER_MB = 0.12
SRAM_AREA_45NM_MM2_PER_MB = 1.25
BASE_POWER_W = 0.25              # control, NoC, DMA engines
# memory subsystem (PHY + DRAM device) power — off-die, does NOT scale
# with the logic technology node
MEM_POWER_W = {19.2e9: 0.9, 38e9: 1.2, 460e9: 11.5}

# DeepScaleTool-style 45 nm -> 14 nm scaling
SCALE_POWER_14NM = 0.285
SCALE_AREA_14NM = 0.115


def dsa_power_w(cfg: DSAConfig, tech: str = "14nm") -> float:
    logic45 = (cfg.pe_x * cfg.pe_y * PE_POWER_45NM_W
               + (cfg.scratchpad_bytes / (1 << 20)) * SRAM_POWER_45NM_W_PER_MB
               + BASE_POWER_W)
    scale = SCALE_POWER_14NM if tech == "14nm" else 1.0
    return logic45 * scale + MEM_POWER_W.get(cfg.mem_bw, 1.2)


def dsa_area_mm2(cfg: DSAConfig, tech: str = "14nm") -> float:
    a45 = (cfg.pe_x * cfg.pe_y * PE_AREA_45NM_MM2
           + (cfg.scratchpad_bytes / (1 << 20)) * SRAM_AREA_45NM_MM2_PER_MB
           + 2.0)
    return a45 * (SCALE_AREA_14NM if tech == "14nm" else 1.0)
