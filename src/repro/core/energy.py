"""System energy model (§VI-A Power measurements).

E = sum over phases of (component power x phase time):
  * compute device at TDP-scaled utilization while computing, idle otherwise
  * host/server CPU during system-stack, network and I/O phases
  * PCIe at per-bit transfer energy (Zeppelin-style ~5 pJ/bit effective)
Network (Ethernet/Internet) power is omitted, as in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.latency import LatencyModel
from repro.core.platforms import Platform, PLATFORMS
from repro.core.workloads import Workload

HOST_CPU_ACTIVE_W = 120.0      # storage/compute node host during stack+net
HOST_CPU_LIGHT_W = 45.0        # host while the DSA/NS device computes
PCIE_PJ_PER_BIT = 5.0


def compute_utilization(plat: Platform) -> float:
    """Average device utilization while computing: systolic DSA/FPGA
    dataflows keep more of the array busy than a cache-bound CPU/GPU."""
    return 0.85 if plat.kind in ("dsa", "fpga") else 0.75


def node_power_w(plat: Platform, busy: bool) -> float:
    """Steady-state wall power of one powered fleet node.

    Idle nodes draw ``plat.idle_w``; a node with a copy in service adds the
    TDP-scaled utilization share — the same convention
    :func:`pipeline_energy_j` applies to the compute phase.  This is the
    per-server model the autoscaling evaluation
    (:mod:`repro.core.autoscale`) integrates over busy/powered seconds;
    powered-off servers draw nothing.
    """
    if not busy:
        return plat.idle_w
    return plat.idle_w + (plat.tdp_w - plat.idle_w) * compute_utilization(plat)


def pipeline_energy_j(lm: LatencyModel, plat: Platform, wl: Workload, *,
                      batch: int = 1, q=0.5, dsa_cfg=None,
                      extra_accel_funcs: int = 0) -> Dict[str, float]:
    bd = lm.pipeline_breakdown(plat, wl, batch=batch, q=q, dsa_cfg=dsa_cfg,
                               extra_accel_funcs=extra_accel_funcs)
    util = compute_utilization(plat)
    e: Dict[str, float] = {}
    e["compute"] = bd["compute"] * (plat.idle_w +
                                    (plat.tdp_w - plat.idle_w) * util)
    # host CPU burns cycles on stack / network / driver phases
    e["host"] = (bd["stack"] + bd["net"]) * HOST_CPU_ACTIVE_W \
        + (bd["driver"] + bd["io"]) * HOST_CPU_LIGHT_W \
        + (bd["compute"] * (HOST_CPU_LIGHT_W
                            if plat.location == "near_storage" else
                            HOST_CPU_ACTIVE_W))
    moved_bytes = (wl.request_bytes + wl.input_bytes + wl.output_bytes) * batch
    e["pcie"] = moved_bytes * 8 * PCIE_PJ_PER_BIT * 1e-12 * 2
    e["total"] = sum(v for k, v in e.items() if k != "total")
    return e


def energy_reduction_vs_baseline(lm: LatencyModel, wl: Workload,
                                 plat_name: str, **kw) -> float:
    base = pipeline_energy_j(lm, PLATFORMS["Baseline-CPU"], wl, **kw)["total"]
    tgt = pipeline_energy_j(lm, PLATFORMS[plat_name], wl, **kw)["total"]
    return base / tgt
