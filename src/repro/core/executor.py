"""End-to-end pipeline executor: runs Table I pipelines NUMERICALLY on JAX
(the near-storage DSA path uses the Pallas kernels), while the analytical
models account latency/energy/cost for the deployment being simulated.

This is the bridge between the paper's system model and the real compute
substrate: f1 pre-processing runs on the vector engine (normalize / cast /
quantize), f2 inference on the systolic kernels, f3 post-processing on the
host — matching Fig. 2 / Fig. 3(b).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.energy import pipeline_energy_j
from repro.core.function import Pipeline, standard_pipeline
from repro.core.latency import LatencyModel
from repro.core.platforms import PLATFORMS, Platform
from repro.kernels import ops
from repro.models import vision


@dataclass
class ExecutionReport:
    result: Any
    latency_breakdown: Dict[str, float]
    energy_breakdown: Dict[str, float]
    platform: str
    accelerated: bool


def _preprocess_vector_engine(img: jax.Array, use_kernel: bool) -> jax.Array:
    """f1: normalize + cast — the DSA vector engine's job."""
    flat = img.reshape(img.shape[0], -1).astype(jnp.float32)
    n = flat.shape[1]
    scale = jnp.full((n,), 1.0 / 127.5)
    bias = jnp.full((n,), -1.0)
    if use_kernel:
        out = ops.affine_act(flat, scale, bias, act="none")
    else:
        out = flat * scale + bias
    return out.reshape(img.shape)


_MODEL_BUILDERS: Dict[str, Tuple[Callable, Callable, dict]] = {
    "asset_damage": (vision.resnet50_init, vision.resnet50_apply,
                     {"width": 0.125}),
    "content_moderation": (vision.effnet_init, vision.effnet_apply,
                           {"width": 0.25}),
    "clinical": (vision.fcn_init, vision.fcn_apply, {"width": 0.125}),
    "ppe_detection": (vision.yolov3_init, vision.yolov3_apply,
                      {"width": 0.125}),
    "remote_sensing": (vision.vit_init, vision.vit_apply, {}),
}


class DSCSExecutor:
    """Executes one Table I pipeline end-to-end in a chosen deployment."""

    def __init__(self, workload_name: str, *, platform: str = "DSCS-Serverless",
                 image_size: int = 64, seed: int = 0):
        self.pipeline = standard_pipeline(
            workload_name, accelerate=(platform == "DSCS-Serverless"))
        self.platform = PLATFORMS[platform]
        self.lm = LatencyModel(seed=seed)
        self.image_size = image_size
        key = jax.random.PRNGKey(seed)
        if workload_name in _MODEL_BUILDERS:
            init, apply, kw = _MODEL_BUILDERS[workload_name]
            self.params = init(key, **kw)
            self._apply = apply
        elif workload_name == "credit_risk":
            self.params = jax.random.normal(key, (200, 1)) * 0.1
            self._apply = lambda p, x, use_kernel=False: jax.nn.sigmoid(x @ p)
        else:  # chatbot / translation: tiny LM via the transformer family
            from repro.configs import get_arch
            from repro.models import transformer as T
            cfg = get_arch("qwen3-8b").reduced()
            self.params = T.init_params(cfg, key)
            self._cfg = cfg
            self._apply = lambda p, x, use_kernel=False: T.forward(
                self._cfg, p, x)

    def make_request(self, key: jax.Array) -> jax.Array:
        name = self.pipeline.name
        if name == "credit_risk":
            return jax.random.normal(key, (1, 200))
        if name in ("chatbot", "translation"):
            return jax.random.randint(key, (1, 32), 0, 512)
        s = self.image_size
        return jax.random.randint(key, (1, s, s, 3), 0, 256).astype(jnp.uint8)

    def __call__(self, request: jax.Array) -> ExecutionReport:
        accel = self.platform.kind == "dsa"
        name = self.pipeline.name
        # f1 — pre-process
        if request.dtype == jnp.uint8:
            x = _preprocess_vector_engine(request, use_kernel=accel)
        else:
            x = request
        # f2 — inference (systolic kernels on the DSA path)
        if name in _MODEL_BUILDERS:
            y = self._apply(self.params, x, use_kernel=accel)
        else:
            y = self._apply(self.params, x)
        # f3 — post/notify
        if y.ndim >= 2 and y.shape[-1] > 1:
            result = jnp.argmax(y, axis=-1)
        else:
            result = y
        lat = self.lm.pipeline_breakdown(self.platform, self.pipeline.workload)
        en = pipeline_energy_j(self.lm, self.platform, self.pipeline.workload)
        return ExecutionReport(result=result, latency_breakdown=lat,
                               energy_breakdown=en,
                               platform=self.platform.name, accelerated=accel)
