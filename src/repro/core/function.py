"""Serverless function & pipeline abstractions (§V programming model).

A ``FunctionSpec`` is the YAML-file analogue: metadata constraints plus the
``acceleratable`` hint DSCS adds.  A ``Pipeline`` is the DAG of functions
(Fig. 2 — a chain for the Table I suite, but arbitrary DAGs are supported).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.workloads import Workload, WORKLOADS


@dataclass(frozen=True)
class FunctionSpec:
    name: str
    role: str                       # preprocess | inference | postprocess
    acceleratable: bool             # the DSCS YAML hint
    timeout_s: float = 30.0
    memory_mb: int = 1024
    storage_class: str = "standard" # or "Acceleratable_Storage"
    image: str = "repro/runtime:latest"


@dataclass(frozen=True)
class Pipeline:
    name: str
    workload: Workload
    functions: Tuple[FunctionSpec, ...]
    edges: Tuple[Tuple[int, int], ...]   # DAG edges (i -> j)

    def validate(self) -> None:
        n = len(self.functions)
        seen = set()
        for a, b in self.edges:
            assert 0 <= a < n and 0 <= b < n and a < b, "edges must be a DAG"
            seen.add((a, b))
        assert len(seen) == len(self.edges), "duplicate edge"


def is_acceleratable(pipeline: Pipeline) -> bool:
    """True when the pipeline's offloadable prefix (f1 preprocess + f2
    inference — the functions DSCS executes in-storage, Fig. 2) carries
    the ``acceleratable`` hint; f3 notify always runs host-side.  This is
    THE dispatch predicate: the engine routes exactly these pipelines to
    drives, and capacity planners (``EWMAPolicy.for_pipelines``) must
    split traffic with the same rule."""
    return all(f.acceleratable for f in pipeline.functions[:2])


def standard_pipeline(workload_name: str, accelerate: bool = True) -> Pipeline:
    """The Fig. 2 three-function chain for a Table I workload."""
    wl = WORKLOADS[workload_name]
    sc = "Acceleratable_Storage" if accelerate else "standard"
    fns = (
        FunctionSpec(f"{wl.name}-f1-preprocess", "preprocess", accelerate,
                     storage_class=sc),
        FunctionSpec(f"{wl.name}-f2-inference", "inference", accelerate,
                     storage_class=sc),
        FunctionSpec(f"{wl.name}-f3-notify", "postprocess", False),
    )
    return Pipeline(wl.name, wl, fns, ((0, 1), (1, 2)))
