"""Data placement & storage classes (§V).

``Acceleratable_Storage`` routes an application's data onto DSCS-capable
drives at deployment time; payload-size caps (AWS Lambda's 256 KB request
limit) guarantee a request's payload lands on ONE drive, and independent
requests spread across drives for scale-out.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

MAX_PAYLOAD_BYTES = 256 << 10       # AWS Lambda request cap


@dataclass
class Drive:
    drive_id: int
    dscs_capable: bool
    capacity_bytes: int = 4 << 40
    used_bytes: int = 0
    objects: Dict[str, int] = field(default_factory=dict)  # key -> size

    def put(self, key: str, size: int) -> None:
        self.objects[key] = size
        self.used_bytes += size

    def has(self, key: str) -> bool:
        return key in self.objects


class StoragePool:
    """A fleet of drives; some are DSCS (DSA-bearing) drives."""

    def __init__(self, n_plain: int, n_dscs: int):
        self.drives: List[Drive] = (
            [Drive(i, False) for i in range(n_plain)]
            + [Drive(n_plain + i, True) for i in range(n_dscs)])

    def dscs_drives(self) -> List[Drive]:
        return [d for d in self.drives if d.dscs_capable]

    def place(self, key: str, size: int, storage_class: str) -> Drive:
        """Deterministic spread of independent request payloads across the
        drives of the right class (requests are independent, §V)."""
        pool = (self.dscs_drives() if storage_class == "Acceleratable_Storage"
                else self.drives)
        if not pool:
            pool = self.drives
        h = int(hashlib.sha1(key.encode()).hexdigest(), 16)
        # payload-cap invariant: one request payload -> one drive
        assert size <= MAX_PAYLOAD_BYTES or storage_class != "request", size
        drive = pool[h % len(pool)]
        drive.put(key, size)
        return drive

    def locate(self, key: str) -> Optional[Drive]:
        for d in self.drives:
            if d.has(key):
                return d
        return None
