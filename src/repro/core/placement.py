"""Data placement & storage classes (§V).

``Acceleratable_Storage`` routes an application's data onto DSCS-capable
drives at deployment time; payload-size caps (AWS Lambda's 256 KB request
limit) guarantee a request's payload lands on ONE drive, and independent
requests spread across drives for scale-out.

Beyond the paper's static one-replica SHA-1 spread, the pool also computes
**k-way replica sets** via rendezvous (highest-random-weight) hashing —
the deterministic candidate lists the tiered data layer
(:mod:`repro.core.tiering`) routes across — and enforces the invariants
the original seed only pretended to:

  * ``Drive.put`` keeps ``used_bytes`` exact across key overwrites
    (the seed double-counted every overwrite);
  * the 256 KB request-payload cap is a real ``ValueError`` on the
    request-payload storage classes (the seed asserted against a
    nonexistent ``"request"`` class, so the cap was dead code);
  * ``capacity_bytes`` is enforced — a full hash-selected drive spills to
    the least-full eligible drive instead of silently overfilling;
  * ``locate`` is O(1) through a key→drive index maintained by ``place``.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

MAX_PAYLOAD_BYTES = 256 << 10       # AWS Lambda request cap

# Storage classes that hold raw request payloads: §V's one-payload-one-
# drive argument rests on the 256 KB cap, so these classes enforce it.
REQUEST_PAYLOAD_CLASSES = ("request", "Acceleratable_Storage")


@dataclass
class Drive:
    drive_id: int
    dscs_capable: bool
    capacity_bytes: int = 4 << 40
    used_bytes: int = 0
    objects: Dict[str, int] = field(default_factory=dict)  # key -> size

    def put(self, key: str, size: int) -> None:
        """Store (or overwrite) ``key``; accounting stays exact and the
        capacity is enforced — an overflowing put raises without touching
        the stored object."""
        if size < 0:
            raise ValueError(f"negative object size: {size}")
        old = self.objects.get(key, 0)
        if self.used_bytes - old + size > self.capacity_bytes:
            raise ValueError(
                f"drive {self.drive_id} over capacity: "
                f"{self.used_bytes - old + size} > {self.capacity_bytes}")
        self.used_bytes += size - old
        self.objects[key] = size

    def fits(self, key: str, size: int) -> bool:
        """Would ``put(key, size)`` succeed right now?"""
        old = self.objects.get(key, 0)
        return self.used_bytes - old + size <= self.capacity_bytes

    def delete(self, key: str) -> None:
        """Drop ``key`` if present (no-op otherwise); accounting follows."""
        size = self.objects.pop(key, None)
        if size is not None:
            self.used_bytes -= size

    def has(self, key: str) -> bool:
        return key in self.objects


class StoragePool:
    """A fleet of drives; some are DSCS (DSA-bearing) drives."""

    def __init__(self, n_plain: int, n_dscs: int,
                 capacity_bytes: Optional[int] = None):
        kw = {} if capacity_bytes is None else {"capacity_bytes":
                                                capacity_bytes}
        self.drives: List[Drive] = (
            [Drive(i, False, **kw) for i in range(n_plain)]
            + [Drive(n_plain + i, True, **kw) for i in range(n_dscs)])
        self._index: Dict[str, Drive] = {}      # key -> holding drive

    def dscs_drives(self) -> List[Drive]:
        return [d for d in self.drives if d.dscs_capable]

    def _pool_for(self, storage_class: str) -> List[Drive]:
        pool = (self.dscs_drives() if storage_class == "Acceleratable_Storage"
                else self.drives)
        return pool or self.drives

    def place(self, key: str, size: int, storage_class: str) -> Drive:
        """Deterministic spread of independent request payloads across the
        drives of the right class (requests are independent, §V).

        Overwrites land on the drive already holding the key; a full
        hash-selected drive spills to the least-full eligible drive that
        fits (lowest drive id on ties); a pool with no room raises.
        """
        # payload-cap invariant: one request payload -> one drive (§V)
        if storage_class in REQUEST_PAYLOAD_CLASSES and \
                size > MAX_PAYLOAD_BYTES:
            raise ValueError(
                f"request payload {size} B exceeds the "
                f"{MAX_PAYLOAD_BYTES} B cap (storage_class="
                f"{storage_class!r}); §V requires a payload to fit on "
                f"one drive")
        held = self._index.get(key)
        if held is not None:                    # overwrite in place
            held.put(key, size)
            return held
        pool = self._pool_for(storage_class)
        h = int(hashlib.sha1(key.encode()).hexdigest(), 16)
        drive = pool[h % len(pool)]
        if not drive.fits(key, size):           # spill: least-full that fits
            fallback = [d for d in pool if d.fits(key, size)]
            if not fallback:
                raise ValueError(
                    f"no {storage_class!r} drive can hold {size} B "
                    f"(key={key!r})")
            drive = min(fallback, key=lambda d: (d.used_bytes, d.drive_id))
        drive.put(key, size)
        self._index[key] = drive
        return drive

    def replicas(self, key: str, k: int,
                 storage_class: str = "Acceleratable_Storage") -> List[Drive]:
        """The ``k`` distinct drives replica copies of ``key`` map to, by
        rendezvous hashing over the eligible pool: drive ``j`` scores
        ``SHA1(f"{key}|{j}")`` and the top-``k`` scores win (descending,
        drive order breaking exact ties).  Deterministic, and removing a
        drive only remaps the keys it held — the property the tiered data
        layer's replica routing and hot-key migration rely on."""
        pool = self._pool_for(storage_class)
        if k < 1:
            raise ValueError(f"replication factor must be >= 1, got {k}")
        scored = sorted(
            range(len(pool)),
            key=lambda j: int(hashlib.sha1(
                f"{key}|{j}".encode()).hexdigest(), 16),
            reverse=True)
        return [pool[j] for j in scored[:min(k, len(pool))]]

    def locate(self, key: str) -> Optional[Drive]:
        """O(1) via the key→drive index ``place`` maintains; keys put on
        drives directly (bypassing ``place``) fall back to the scan."""
        drive = self._index.get(key)
        if drive is not None and drive.has(key):
            return drive
        for d in self.drives:
            if d.has(key):
                return d
        return None

    def remove(self, key: str) -> None:
        """Drop ``key`` from the pool (index and drive), if present."""
        drive = self._index.pop(key, None)
        if drive is None:
            drive = self.locate(key)
        if drive is not None:
            drive.delete(key)
