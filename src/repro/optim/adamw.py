"""Functional AdamW + cosine schedule + gradient clipping + accumulation.

Optimizer state is a pytree shaped like params (mu/nu fp32) and shards with
the same PartitionSpecs, so FSDP covers optimizer state (ZeRO-style) for
free.  Optional int8 gradient compression (quantize -> dequantize around the
data-parallel reduction; see ``repro.distributed.compression``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    step: jax.Array            # int32 scalar
    mu: Pytree                 # fp32
    nu: Pytree                 # fp32


def init(params: Pytree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def state_shapes(param_shapes: Pytree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                         param_shapes)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=zeros, nu=zeros)


def cosine_schedule(lr: float, warmup: int, total: int) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        s = step.astype(jnp.float32)
        warm = lr * (s + 1.0) / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warmup, warm, cos)
    return sched


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Tuple[Pytree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply(params: Pytree, grads: Pytree, state: AdamWState, *,
          sched: Callable[[jax.Array], jax.Array], b1=0.9, b2=0.95,
          eps=1e-8, weight_decay=0.1, grad_clip=1.0) -> Tuple[Pytree, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, grad_clip)
    step = state.step + 1
    lr = sched(state.step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
