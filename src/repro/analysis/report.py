"""Assemble the EXPERIMENTS.md roofline tables from dry-run JSONs.

Usage: PYTHONPATH=src python -m repro.analysis.report [results/dryrun]
Prints markdown to stdout.
"""
from __future__ import annotations

import glob
import json
import sys
from collections import defaultdict


def load(dirname: str):
    recs = []
    for f in sorted(glob.glob(f"{dirname}/*.json")):
        recs.append(json.load(open(f)))
    return recs


def fmt_bytes(b):
    return f"{b / (1 << 30):.2f}"


def roofline_table(recs, mesh="single", rules="train"):
    rows = []
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| peak GB/chip | MODEL_FLOPS | useful ratio | roofline frac | "
           "what would move the dominant term |")
    sep = "|" + "---|" * 11
    rows.append(hdr)
    rows.append(sep)
    hints = {
        ("collective", "train"): "bf16 cotangent collectives + reduce-scatter "
                                 "instead of all-reduce (sequence parallelism)",
        ("collective", "decode"): "stop FSDP-gathering weights per step: "
                                  "TP-resident (2D) weight layout",
        ("collective", "prefill"): "sequence-parallel norm/residual to halve "
                                   "activation all-reduces",
        ("memory", "train"): "fuse attention score/softmax chain (flash "
                             "kernel) to cut HBM round-trips",
        ("memory", "decode"): "decode is weight/cache-stream bound: int8 "
                              "weights + grouped KV layout",
        ("memory", "prefill"): "flash-attention fusion; avoid fp32 "
                               "score materialization",
        ("compute", "train"): "reduce remat recompute (checkpoint policy: "
                              "save attn outputs)",
        ("compute", "decode"): "batch decode steps (speculative/multi-token)",
        ("compute", "prefill"): "already near compute roofline; improve MXU "
                                "utilization via tile shapes",
    }
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh or r.get("rules", "train") != rules:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                        f"| — | — | — | — | {r['reason']} |")
            continue
        t = r["roofline"]
        kind = ("train" if r["shape"].startswith("train") else
                "prefill" if r["shape"].startswith("prefill") else "decode")
        hint = hints.get((t["dominant"], kind), "")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4g} | "
            f"{t['memory_s']:.4g} | {t['collective_s']:.4g} | "
            f"**{t['dominant']}** | {fmt_bytes(r['memory']['peak_bytes'])} | "
            f"{t['model_flops_total']:.3g} | {t['useful_ratio']:.2f} | "
            f"{t['roofline_fraction']:.3f} | {hint} |")
    return "\n".join(rows)


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | status | peak GB/chip | args GB | "
            "temp GB | FLOPs/chip | bytes/chip | coll GB/chip | collectives |",
            "|" + "---|" * 11]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skipped | — | — | — | — | — | — | {r['reason']} |")
            continue
        m = r["memory"]
        t = r["roofline"]
        kinds = ", ".join(f"{k}:{int(v['count'])}"
                          for k, v in r["raw"]["real"]["coll_detail"].items())
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{fmt_bytes(m['peak_bytes'])} | {fmt_bytes(m['argument_bytes'])} | "
            f"{fmt_bytes(m['temp_bytes'])} | {t['flops_per_chip']:.3g} | "
            f"{t['bytes_per_chip']:.3g} | "
            f"{t['coll_bytes_per_chip'] / (1 << 30):.2f} | {kinds} |")
    return "\n".join(rows)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(d)
    print("### Roofline (single-pod 16x16, baseline rules)\n")
    print(roofline_table(recs, "single"))
    print("\n### Dry-run artifact summary (both meshes)\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
