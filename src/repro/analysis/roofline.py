"""Roofline terms from dry-run artifacts.

Hardware constants (TPU v5e-like target):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link

Terms (seconds, per chip):
  compute    = HLO_FLOPs_per_chip / peak
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_traffic_per_chip / link_bw

MODEL_FLOPS (the "useful work" yardstick):
  train    : 6 * N_active * tokens
  prefill  : 2 * N_active * tokens
  decode   : 2 * N_active * batch       (one token per sequence)
"""
from __future__ import annotations

from dataclasses import dataclass, asdict
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def active_params(cfg: ModelConfig) -> int:
    """Parameter count with MoE experts discounted by k/E."""
    from repro.models.transformer import param_defs, PDef
    import numpy as np
    import jax

    total = 0
    def walk(tree, in_expert=False):
        nonlocal total
        if isinstance(tree, PDef):
            n = int(np.prod(tree.shape))
            if "expert" in (tree.axes or ()):
                n = n * max(cfg.experts_per_token, 1) // max(cfg.num_experts, 1)
            total += n
            return
        if isinstance(tree, dict):
            for v in tree.values():
                walk(v)
        elif isinstance(tree, (list, tuple)):
            for v in tree:
                walk(v)
    walk(param_defs(cfg))
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    model_flops_total: float
    peak_memory_bytes: Optional[float] = None

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — catches remat/redundancy waste."""
        hlo_total = self.flops_per_chip * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU proxy: useful-compute time / bound time."""
        useful_s = self.model_flops_total / self.chips / PEAK_FLOPS
        return useful_s / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> Dict:
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d
