"""Parse collective traffic out of a compiled (SPMD-partitioned) HLO module.

``cost_analysis()`` does not report collective bytes, so we regex the
module text for ``all-reduce | all-gather | reduce-scatter | all-to-all |
collective-permute`` result shapes and convert to estimated per-device link
traffic:

  all-gather        : result bytes              (each device receives ~result)
  all-reduce        : 2 x result bytes          (ring: reduce-scatter + all-gather)
  reduce-scatter    : result bytes x group size (input flows through the ring)
  all-to-all        : result bytes
  collective-permute: result bytes

Known limitation (documented in DESIGN.md): ops inside a ``while`` body
appear once in the text; the dry-run corrects for scan trip counts with its
L0/L1 variant protocol.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# result of a collective:  %x = bf16[8,16]{1,0} all-gather(...)
_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-kind {count, result_bytes, traffic_bytes} from module text."""
    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0.0, "traffic_bytes": 0.0})
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        # -done ops re-state the result of -start; count each op once
        if "-done(" in line:
            continue
        rb = _shape_bytes(type_str)
        gs = _group_size(line)
        if kind == "all-reduce":
            traffic = 2.0 * rb * (gs - 1) / max(gs, 1)
        elif kind == "all-gather":
            traffic = rb * (gs - 1) / max(gs, 1)
        elif kind == "reduce-scatter":
            traffic = rb * (gs - 1)
        else:
            traffic = rb
        d = out[kind]
        d["count"] += 1
        d["result_bytes"] += rb
        d["traffic_bytes"] += traffic
    return dict(out)


def total_collective_bytes(hlo_text: str) -> float:
    return sum(v["traffic_bytes"] for v in collective_stats(hlo_text).values())
