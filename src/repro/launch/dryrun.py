import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers+compiles the right step function (train / prefill / decode) from
     ShapeDtypeStructs — params via shape trees, no allocation,
  3. prints ``compiled.memory_analysis()`` (fits-in-HBM proof) and
     ``compiled.cost_analysis()`` (FLOPs/bytes for the roofline),
  4. applies the L0/L1 scan-correction protocol: XLA's cost analysis counts a
     ``while`` body once, so we compile variants with 0 and 1 scanned layer
     groups (MoE token-block scan disabled, exact attention via unrolled
     chunks) and extrapolate  total = V0 + G*(V1 - V0)  (+ encoder variant
     for enc-dec archs),
  5. parses collective traffic from the partitioned HLO text,
  6. writes one resumable JSON per cell under --out.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.analysis import hlo as HLO
from repro.analysis import roofline as RL
from repro.configs import ARCHS, SHAPES_BY_NAME, TrainConfig, cells, get_arch
from repro.distributed import sharding as SHD
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh


def _variant(cfg, groups: int, enc_layers=None):
    period = len(cfg.block_pattern)
    rem = cfg.num_layers % period
    upd = dict(
        num_layers=groups * period + rem,
        moe_block_tokens=0,          # exact MoE flops (no inner scan)
        scan_layers=True,
    )
    if cfg.encoder_layers:
        upd["encoder_layers"] = 0 if enc_layers is None else enc_layers
    return dataclasses.replace(cfg, **upd)


def _lower_compile(cfg, shape, mesh, rules, *, want_memory: bool):
    """Lower+compile one variant; return metrics dict."""
    kind = shape.kind
    sh = ST.shardings_for(cfg, mesh, shape, rules, with_opt=(kind == "train"))
    tcfg = TrainConfig()
    t0 = time.time()
    if kind == "train":
        fn = ST.make_train_step(cfg, mesh, tcfg, rules)
        args = (sh["param_shapes"], sh["opt_shapes"], sh["batch_shapes"])
        in_sh = (sh["params"], sh["opt"], sh["batch"])
        jfn = jax.jit(fn, in_shardings=in_sh, donate_argnums=(0, 1))
    elif kind == "prefill":
        fn = ST.make_prefill_step(cfg, mesh, rules)
        args = (sh["param_shapes"], sh["batch_shapes"])
        jfn = jax.jit(fn, in_shardings=(sh["params"], sh["batch"]))
    else:  # decode
        fn = ST.make_decode_step(cfg, mesh, rules)
        args = (sh["param_shapes"], sh["cache_shapes"], sh["batch_shapes"])
        jfn = jax.jit(fn, in_shardings=(sh["params"], sh["cache"], sh["batch"]),
                      donate_argnums=(1,))
    with mesh:
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    colls = HLO.collective_stats(txt)
    rec = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": sum(v["traffic_bytes"] for v in colls.values()),
        "coll_detail": colls,
        "t_lower_s": t_lower,
        "t_compile_s": t_compile,
    }
    if want_memory:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        rec["memory"]["peak_bytes"] = (
            rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
            + rec["memory"]["output_bytes"] - rec["memory"]["alias_bytes"])
    return rec


def _combine(v0, v1, groups, venc=None, enc_layers=0):
    out = {}
    for key in ("flops", "bytes", "coll_bytes"):
        total = v0[key] + groups * (v1[key] - v0[key])
        if venc is not None:
            total += enc_layers * (venc[key] - v0[key])
        out[key] = total
    return out


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: Path,
             rules_name: str = "train", force: bool = False,
             overrides: dict = None, tag_suffix: str = "") -> dict:
    cfg = get_arch(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES_BY_NAME[shape_name]
    tag = f"{arch}__{shape_name}__{mesh_name}__{rules_name}{tag_suffix}"
    out_path = out_dir / f"{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "rules": rules_name, "status": "ok"}
    if shape.name == "long_500k" and not cfg.subquadratic:
        rec["status"] = "skipped"
        rec["reason"] = "full attention (quadratic); skipped per assignment rules"
        out_dir.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    rules = {"train": SHD.TRAIN_RULES, "tp": SHD.TP_RULES,
             "seqpar": SHD.SEQPAR_RULES, "decode2d": SHD.DECODE_RULES}[rules_name]
    period = len(cfg.block_pattern)
    groups = cfg.num_layers // period

    try:
        cfg_run = dataclasses.replace(cfg, attn_chunk=512)
        t0 = time.time()
        # memory-analysis variant: q-chunk loop as a scan (sequential buffer
        # liveness, matches how the TPU kernel would stage VMEM tiles);
        # FLOP variants below unroll it for exact cost accounting.
        real = _lower_compile(dataclasses.replace(cfg_run, attn_unroll=False),
                              shape, mesh, rules, want_memory=True)
        v0 = _lower_compile(_variant(cfg_run, 0), shape, mesh, rules,
                            want_memory=False)
        v1 = _lower_compile(_variant(cfg_run, 1), shape, mesh, rules,
                            want_memory=False)
        venc = None
        if cfg.encoder_layers and shape.kind != "decode":
            venc = _lower_compile(_variant(cfg_run, 0, enc_layers=1), shape,
                                  mesh, rules, want_memory=False)
        corr = _combine(v0, v1, groups, venc, cfg.encoder_layers)
        chips = mesh.size
        mf = RL.model_flops(cfg, shape)
        terms = RL.RooflineTerms(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            flops_per_chip=corr["flops"], bytes_per_chip=corr["bytes"],
            coll_bytes_per_chip=corr["coll_bytes"], model_flops_total=mf,
            peak_memory_bytes=real["memory"]["peak_bytes"])
        rec.update(
            chips=chips, groups=groups, period=period,
            raw={"real": real, "v0": v0, "v1": v1,
                 **({"venc": venc} if venc else {})},
            corrected=corr,
            memory=real["memory"],
            roofline=terms.to_dict(),
            wall_s=time.time() - t0,
        )
        print(f"[dryrun] {tag}: dominant={terms.dominant} "
              f"compute={terms.compute_s:.4f}s memory={terms.memory_s:.4f}s "
              f"coll={terms.collective_s:.4f}s frac={terms.roofline_fraction:.3f} "
              f"peakGB={real['memory']['peak_bytes']/1e9:.2f} "
              f"wall={rec['wall_s']:.0f}s", flush=True)
        print(f"  memory_analysis: {real['memory']}", flush=True)
        print(f"  cost_analysis: flops/chip={corr['flops']:.3e} "
              f"bytes/chip={corr['bytes']:.3e} coll/chip={corr['coll_bytes']:.3e}",
              flush=True)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()
        print(f"[dryrun] {tag}: FAILED {rec['error']}", flush=True)

    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1, default=float))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--rules", default="train")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        todo = [(a.name, s.name) for a, s, _ in cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for arch, shape in todo:
        for mesh_name in meshes:
            rec = run_cell(arch, shape, mesh_name, out_dir, args.rules,
                           force=args.force)
            n_ok += rec["status"] == "ok"
            n_skip += rec["status"] == "skipped"
            n_err += rec["status"] == "error"
    print(f"[dryrun] done: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
