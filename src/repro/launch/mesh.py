"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips ("data","model");
multi-pod: 2x16x16 = 512 chips ("pod","data","model").
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    # jax.sharding.AxisType only exists on newer jax; older versions take no
    # axis_types argument and default every axis to Auto anyway.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_local_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist (1 on the CPU test container)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"), **_mesh_kwargs(2))
