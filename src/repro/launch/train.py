"""Training launcher: ``python -m repro.launch.train --arch qwen3-8b --smoke``.

Runs the full production loop at any scale: mesh construction, sharded
init, deterministic resumable data, AdamW train steps, periodic atomic
checkpoints, crash-restart resume (``--resume``), and straggler-aware step
timing logs.  ``--smoke`` substitutes the reduced config so the identical
code path runs on the CPU container.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import manager as ckpt
from repro.configs import TrainConfig, get_arch
from repro.data.pipeline import TokenStream
from repro.distributed import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import transformer as T
from repro.optim import adamw


def train(arch: str, *, smoke: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 128, ckpt_dir: str = "/tmp/repro_ckpt", resume: bool = False,
          checkpoint_every: int = 20, production_mesh: bool = False,
          log_every: int = 10, microbatches: int = 1, seed: int = 0,
          stop_at: int = 0):
    """``stop_at`` simulates a crash: run ends early but the LR schedule
    and checkpoints are laid out for the full ``steps`` run, so a resumed
    run continues the exact trajectory."""
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if production_mesh else make_local_mesh())
    tcfg = TrainConfig(total_steps=steps, warmup_steps=max(2, steps // 10),
                       microbatches=microbatches,
                       checkpoint_every=checkpoint_every, checkpoint_dir=ckpt_dir)
    rules = SH.TRAIN_RULES

    pshapes = T.param_shapes(cfg)
    paxes = T.param_logical_axes(cfg)
    pspec = SH.param_spec_tree(pshapes, paxes, rules, mesh)
    ns = lambda sp: NamedSharding(mesh, sp)
    psh = jax.tree.map(ns, pspec, is_leaf=lambda x: isinstance(x, P))

    with mesh:
        params = jax.jit(partial(T.init_params, cfg),
                         out_shardings=psh)(jax.random.PRNGKey(seed))
        opt_state = adamw.init(params)
        start_step = 0
        if resume and ckpt.latest_step(ckpt_dir) is not None:
            (params, opt_state), start_step, _ = ckpt.restore(
                ckpt_dir, (params, opt_state))
            print(f"[train] resumed from step {start_step}")

        step_fn = jax.jit(ST.make_train_step(cfg, mesh, tcfg, rules),
                          donate_argnums=(0, 1))
        bshard = {k: ns(SH.batch_spec(v.shape, rules, mesh))
                  for k, v in TokenStream(cfg, batch, seq, seed).batch_at(0).items()}
        stream = TokenStream(cfg, batch, seq, seed, shardings=bshard)

        losses = []
        t_last = time.time()
        end = min(steps, stop_at) if stop_at else steps
        for step in range(start_step, end):
            batch_data = stream.batch_at(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch_data)
            losses.append(float(metrics["loss"]))
            if (step + 1) % log_every == 0 or step == end - 1:
                dt = (time.time() - t_last) / log_every
                print(f"[train] step {step + 1}/{steps} "
                      f"loss={losses[-1]:.4f} gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} {dt * 1e3:.0f} ms/step",
                      flush=True)
                t_last = time.time()
            if (step + 1) % checkpoint_every == 0 or step == end - 1:
                ckpt.save(ckpt_dir, step + 1, (params, opt_state),
                          extras={"arch": arch, "seed": seed})
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    losses = train(args.arch, smoke=args.smoke, steps=args.steps,
                   batch=args.batch, seq=args.seq, resume=args.resume,
                   microbatches=args.microbatches, ckpt_dir=args.ckpt_dir)
    print(f"[train] first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
