"""Serving launcher: batched prefill + decode with a KV cache.

``python -m repro.launch.serve --arch qwen3-8b --batch 4 --prompt 64 --gen 16``

The DSCS analogy: requests land on the drive-shard ("data" axis) that holds
their payload; decode steps run where the KV cache lives — dispatch-to-data
end to end.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.data.pipeline import RequestStream
from repro.distributed import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_local_mesh
from repro.models import decode as DE
from repro.models import transformer as T


def serve(arch: str, *, smoke: bool = True, batch: int = 4, prompt: int = 64,
          gen: int = 16, seed: int = 0, greedy: bool = True):
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    mesh = make_local_mesh()
    rules = SH.TRAIN_RULES
    with mesh:
        params = T.init_params(cfg, jax.random.PRNGKey(seed))
        prefill_fn = jax.jit(ST.make_prefill_step(cfg, mesh, rules))
        decode_fn = jax.jit(ST.make_decode_step(cfg, mesh, rules),
                            donate_argnums=(1,))
        reqs = RequestStream(cfg, batch, prompt, seed).requests_at(0)
        batch_in = {"tokens": jnp.asarray(reqs["tokens"])}
        if cfg.frontend == "audio_frames":
            batch_in["encoder_frames"] = jnp.zeros(
                (batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        if cfg.frontend == "vision_patches":
            batch_in["frontend_embeds"] = jnp.zeros(
                (batch, cfg.frontend_seq, cfg.d_model), cfg.dtype)

        t0 = time.time()
        logits, cache = prefill_fn(params, batch_in)
        # grow the cache to prompt+gen capacity for attention layers
        cache = _grow_cache(cfg, cache, batch, prompt + gen)
        t_prefill = time.time() - t0

        tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out = [tokens]
        t0 = time.time()
        for _ in range(gen - 1):
            logits, cache = decode_fn(params, cache, {"tokens": tokens})
            tokens = (jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
                      if greedy else tokens)
            out.append(tokens)
        t_decode = time.time() - t0
        gen_tokens = jnp.concatenate(out, axis=1)
        return {
            "generated": np.asarray(gen_tokens),
            "prefill_s": t_prefill,
            "decode_s_per_token": t_decode / max(gen - 1, 1),
        }


def _grow_cache(cfg, cache, batch: int, capacity: int):
    """Re-embed a prompt-sized cache into a ``capacity``-sized one (prefix
    copy along the seq dim; ring/state caches are size-invariant)."""
    tmpl = DE.cache_shapes(cfg, batch, capacity)
    new = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tmpl)

    def copy(dst, src):
        if dst.shape == src.shape:
            return src
        idx = tuple(slice(0, s) for s in src.shape)
        return dst.at[idx].set(src)

    new = jax.tree.map(copy, new, cache)
    new["pos"] = cache["pos"]
    return new


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()
    out = serve(args.arch, smoke=args.smoke, batch=args.batch,
                prompt=args.prompt, gen=args.gen)
    print(f"[serve] generated shape {out['generated'].shape} "
          f"prefill {out['prefill_s']*1e3:.0f}ms "
          f"decode {out['decode_s_per_token']*1e3:.1f}ms/token")


if __name__ == "__main__":
    main()
