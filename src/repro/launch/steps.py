"""Step-function builders: train_step / prefill_step / decode_step.

Each builder returns the jittable function plus the sharding trees the
launcher (or dry-run) needs for ``in_shardings``/``out_shardings``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.distributed import sharding as SH
from repro.models import decode as DE
from repro.models import transformer as T
from repro.optim import adamw

Pytree = Any


def loss_fn(cfg: ModelConfig, params, batch, shard) -> jax.Array:
    logits = T.forward(
        cfg, params, batch["tokens"],
        frontend_embeds=batch.get("frontend_embeds"),
        encoder_frames=batch.get("encoder_frames"),
        shard=shard)
    loss = T.softmax_xent(logits, batch["labels"])
    if cfg.num_experts:
        # aux losses are already folded into moe_ffn's output path cheaply;
        # the main CE is the training signal here.
        pass
    return loss


def make_train_step(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig,
                    rules=None):
    rules = rules or SH.TRAIN_RULES
    shard = SH.make_act_sharder(mesh, rules)
    sched = adamw.cosine_schedule(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            # gradient accumulation over microbatches (sequential scan)
            mb = jax.tree.map(
                lambda x: x.reshape((tcfg.microbatches,
                                     x.shape[0] // tcfg.microbatches) + x.shape[1:]),
                batch)

            def body(acc, b):
                l, g = jax.value_and_grad(loss_fn, argnums=1)(cfg, params, b, shard)
                return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g)), None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (lsum, gsum), _ = jax.lax.scan(body, zero, mb)
            loss = lsum / tcfg.microbatches
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
        else:
            loss, grads = jax.value_and_grad(loss_fn, argnums=1)(
                cfg, params, batch, shard)
        if tcfg.grad_compression == "int8":
            # int8 + error-feedback DP gradient compression (the error
            # state rides in metrics-free closure-less form: stateless EF
            # per step is applied by the launcher when enabled; here we
            # apply the quantize->dequantize wire transform)
            from repro.distributed import compression as GC
            err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                               grads)
            grads, _ = GC.compress_grads(grads, err)
        params, opt_state, metrics = adamw.apply(
            params, grads, opt_state, sched=sched, b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, rules=None):
    rules = rules or SH.TRAIN_RULES
    shard = SH.make_act_sharder(mesh, rules)

    def prefill_step(params, batch):
        logits, cache = DE.prefill(
            cfg, params, batch["tokens"],
            encoder_frames=batch.get("encoder_frames"),
            frontend_embeds=batch.get("frontend_embeds"),
            shard=shard)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Mesh, rules=None):
    rules = rules or SH.TRAIN_RULES
    shard = SH.make_act_sharder(mesh, rules)

    def decode_step(params, cache, batch):
        logits, cache = DE.decode_step(cfg, params, cache, batch["tokens"],
                                       shard=shard)
        return logits, cache

    return decode_step


# ---------------------------------------------------------------------------
# sharding trees for a cell
# ---------------------------------------------------------------------------

def shardings_for(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                  rules=None, with_opt: bool = False):
    """(param, [opt], batch, [cache]) NamedSharding trees for one cell."""
    rules = rules or SH.TRAIN_RULES
    pshapes = T.param_shapes(cfg)
    paxes = T.param_logical_axes(cfg)
    pspec = SH.param_spec_tree(pshapes, paxes, rules, mesh)
    ns = lambda sp: NamedSharding(mesh, sp)
    psh = jax.tree.map(ns, pspec, is_leaf=lambda x: isinstance(x, P))

    from repro.launch.specs import input_specs
    bspecs = input_specs(cfg, shape)
    bsh = {}
    for k, s in bspecs.items():
        if k == "tokens" or k == "labels" or s.ndim >= 2:
            bsh[k] = ns(SH.batch_spec(s.shape, rules, mesh))
        else:
            bsh[k] = ns(P())

    out = {"params": psh, "param_shapes": pshapes, "batch": bsh,
           "batch_shapes": bspecs}
    if with_opt:
        oshapes = adamw.state_shapes(pshapes)
        osh = adamw.AdamWState(
            step=ns(P()),
            mu=jax.tree.map(ns, pspec, is_leaf=lambda x: isinstance(x, P)),
            nu=jax.tree.map(ns, pspec, is_leaf=lambda x: isinstance(x, P)))
        out["opt"] = osh
        out["opt_shapes"] = oshapes
    if shape.kind == "decode":
        cshapes = DE.cache_shapes(cfg, shape.global_batch, shape.seq_len)
        caxes = DE.cache_logical_axes(cfg, shape.global_batch, shape.seq_len)
        cspec = SH.param_spec_tree(cshapes, caxes, rules, mesh)
        out["cache"] = jax.tree.map(ns, cspec, is_leaf=lambda x: isinstance(x, P))
        out["cache_shapes"] = cshapes
    return out
