"""ShapeDtypeStruct stand-ins for every model input (dry-run contract).

``input_specs(arch, shape)`` returns weak-type-correct, shardable specs with
no device allocation — the same pattern the dry-run, the roofline pass and
the benchmarks consume.  Frontends are STUBS: audio/vision archs receive
precomputed frame/patch embeddings here.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

Pytree = Any


def _frontend_specs(cfg: ModelConfig, batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio_frames":
        return {"encoder_frames": jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), dt)}
    if cfg.frontend == "vision_patches":
        return {"frontend_embeds": jax.ShapeDtypeStruct(
            (batch, cfg.frontend_seq, cfg.d_model), dt)}
    return {}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs for one (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        out.update(_frontend_specs(cfg, B))
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        out.update(_frontend_specs(cfg, B))
        return out
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    raise ValueError(shape.kind)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, key: jax.Array) -> Pytree:
    """Materialize a random batch matching ``input_specs`` (tests/examples)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if s.dtype == jnp.int32:
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size, jnp.int32)
        else:
            out[name] = (jax.random.normal(k, s.shape, jnp.float32) * 0.02).astype(s.dtype)
    return out
