"""Segmented Lindley recurrence as a Pallas TPU kernel.

Solves a batch of independent FCFS queues: for each row (queue) with
arrivals ``t`` and service demands ``s`` along the depth axis, the
service start is ``start_d = max(t_d, m_d + prev_d)`` with
``prev_d = cumsum(s)_d - s_d`` and ``m_d`` the running max of
``t - prev``.  Rows ride the lane dimension, the depth axis is scanned
sequentially across grid blocks with a grid-carried fp64 VMEM state of
``(running cumsum, running max)`` per lane.

The step performs the *same* float64 operations in the same order as
the numpy backend in :mod:`repro.core.lindley` (including ``prev``
recomputed as ``c - s`` rather than carried directly), so interpret-mode
output is bit-identical to numpy — pinned in ``tests/test_kernels.py``.
Zero-padded tail blocks are harmless: position ``d`` only depends on
positions ``<= d`` of the same row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _lindley_kernel(t_ref, s_ref, o_ref, st_ref, *, bd: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        st_ref[0, :] = jnp.zeros_like(st_ref[0, :])       # running cumsum
        st_ref[1, :] = jnp.full_like(st_ref[1, :], -jnp.inf)  # running max

    def step(d, carry):
        c, m = carry
        s = s_ref[d, :]
        t = t_ref[d, :]
        c = c + s
        prev = c - s              # matches numpy's C - S, not c_{d-1}
        m = jnp.maximum(m, t - prev)
        o_ref[d, :] = jnp.maximum(t, m + prev)
        return c, m

    c, m = jax.lax.fori_loop(0, bd, step, (st_ref[0, :], st_ref[1, :]))
    st_ref[0, :] = c
    st_ref[1, :] = m


@functools.partial(jax.jit, static_argnames=("br", "bd", "interpret"))
def lindley_scan(t: jax.Array, s: jax.Array, *, br: int = 128,
                 bd: int = 128, interpret: bool = False) -> jax.Array:
    """t/s (R, W): R queues, depth W (zero pad past each queue's length)
    -> service starts (R, W)."""
    R, W = t.shape
    br, bd = min(br, R), min(bd, W)
    Rp = -(-R // br) * br
    Wp = -(-W // bd) * bd
    # transpose to (depth, rows): rows on lanes, depth scanned
    tp = jnp.pad(t, ((0, Rp - R), (0, Wp - W))).T
    sp = jnp.pad(s, ((0, Rp - R), (0, Wp - W))).T
    blk = lambda ir, it: (it, ir)
    out = pl.pallas_call(
        functools.partial(_lindley_kernel, bd=bd),
        grid=(Rp // br, Wp // bd),
        in_specs=[
            pl.BlockSpec((bd, br), blk),
            pl.BlockSpec((bd, br), blk),
        ],
        out_specs=pl.BlockSpec((bd, br), blk),
        out_shape=jax.ShapeDtypeStruct((Wp, Rp), t.dtype),
        scratch_shapes=[pltpu.VMEM((2, br), t.dtype)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tp, sp)
    return out.T[:R, :W]
