"""Pure-jnp oracles for every Pallas kernel (shape/dtype-sweep targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.kernels.systolic_matmul import _ACTS


def matmul_ref(x, w, b=None, *, act: str = "none", out_dtype=None):
    acc = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        acc = acc + b.astype(jnp.float32)
    return _ACTS[act](acc).astype(out_dtype or x.dtype)


def attention_ref(q, k, v, *, causal=True, window=0):
    """q (B,H,Sq,D); k/v (B,KV,Skv,D) — dense masked softmax."""
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)


def affine_act_ref(x, scale, bias, *, act="none", out_dtype=None):
    y = x.astype(jnp.float32) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return _ACTS[act](y).astype(out_dtype or x.dtype)


def quantize_int8_ref(x):
    x32 = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_ref(q, scale, *, out_dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(out_dtype)


def rglru_ref(x, gx, ga, log_a, h0):
    """Associative-scan RG-LRU (models.layers.rglru)."""
    seq, _ = L.rglru(x, gx, ga, log_a, h0)
    return seq


def ssd_ref(x, dt, A, Bm, Cm, *, chunk):
    """Chunked SSD via associative scan (models.layers.ssd_chunked)."""
    return L.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)


def lindley_ref(t, s):
    """Batched FCFS Lindley starts: t/s (R, W) -> start (R, W)."""
    c = jnp.cumsum(s, axis=1)
    prev = c - s
    m = jax.lax.cummax(t - prev, axis=1)
    return jnp.maximum(t, m + prev)
