"""Mamba-2 SSD (state-space duality) chunk kernel.

One grid cell processes one (batch, head) x chunk tile: the intra-chunk
quadratic term runs on the MXU ((Q,Q) and (Q,N) matmuls inside VMEM), the
inter-chunk state is carried in an fp32 VMEM scratch across the sequential
chunk grid dimension — the Pallas analogue of ``models.layers.ssd_chunked``
(its associative-scan formulation is the pure-jnp oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, hout_ref,
                state_ref, *, Q: int, nc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)                 # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)               # (Q,)
    a = a_ref[0, 0].astype(jnp.float32)              # scalar (negative)
    b = b_ref[0].astype(jnp.float32)                 # (Q, N)
    c = c_ref[0].astype(jnp.float32)                 # (Q, N)

    dA = dt * a
    cum = jnp.cumsum(dA)
    seg = cum[-1]

    # intra-chunk (quadratic within Q)
    Li = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    CB = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    W = jnp.where(tri, jnp.exp(Li) * CB, 0.0) * dt[None, :]
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, state_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)

    # state update
    w = dt * jnp.exp(seg - cum)                      # (Q,)
    state_ref[...] = jnp.exp(seg) * state_ref[...] + jax.lax.dot_general(
        x, b * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ic == nc - 1)
    def _final():
        hout_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 128, interpret: bool = False):
    """x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,G,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    xb = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtb = dt.transpose(0, 2, 1).reshape(B * H, S)
    bb = Bm.transpose(0, 2, 1, 3).reshape(B * G, S, N)
    cb = Cm.transpose(0, 2, 1, 3).reshape(B * G, S, N)
    a2 = A.reshape(H, 1)

    kernel = functools.partial(_ssd_kernel, Q=Q, nc=nc)
    grp = lambda bh, H=H, G=G, rep=rep: (bh // H) * G + ((bh % H) // rep)
    y, hfin = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, Q), lambda bh, ic: (bh, ic)),
            pl.BlockSpec((1, 1), lambda bh, ic, H=H: (bh % H, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, ic, grp=grp: (grp(bh), ic, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, ic, grp=grp: (grp(bh), ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, P, N), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B * H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xb, dtb, a2, bb, cb)
    return (y.reshape(B, H, S, P).transpose(0, 2, 1, 3),
            hfin.reshape(B, H, P, N))
