"""Blocked (flash-style) attention as a Pallas TPU kernel.

Online-softmax over K/V blocks with fp32 VMEM accumulators; supports GQA
(kv-head groups via BlockSpec index maps), causal masking and sliding
windows.  Grid: (batch*heads, Sq/bq, Skv/bk) with the K/V dimension
innermost and sequential — the same tiling the pure-JAX
``models.layers.blocked_attention`` oracle uses, so the two validate against
each other across shapes/dtypes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int, out_dtype):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, bq: int = 128,
                    bk: int = 128, interpret: bool = False) -> jax.Array:
    """q (B, H, Sq, D); k/v (B, KV, Skv, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    bq, bk = min(bq, Sq), min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nk = Skv // bk
    scale = 1.0 / math.sqrt(D)

    qr = q.reshape(B * H, Sq, D)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk,
                               out_dtype=q.dtype)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda bh, iq, ik, H=H, G=G: (bh // H, (bh % H) // G,
                                                       ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda bh, iq, ik, H=H, G=G: (bh // H, (bh % H) // G,
                                                       ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, k, v)
    return out.reshape(B, H, Sq, D)
