"""RG-LRU linear recurrence as a Pallas TPU kernel.

Sequential over time blocks (grid-carried fp32 VMEM state), parallel over
(batch, width) tiles.  Within a time block the recurrence runs as an
in-kernel ``fori_loop`` — the TPU analogue of the paper's vector-engine
executing a pointwise recurrent update close to the data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _rglru_kernel(x_ref, gx_ref, ga_ref, la_ref, h0_ref, o_ref, h_ref, *,
                  bs: int, c: float):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    x = x_ref[...].astype(jnp.float32)               # (bb, bs, bw)
    r = jax.nn.sigmoid(ga_ref[...].astype(jnp.float32))
    i = jax.nn.sigmoid(gx_ref[...].astype(jnp.float32))
    log_a = c * r * jax.nn.softplus(la_ref[...].astype(jnp.float32))[None]
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * i * x

    def step(t, h):
        h = a[:, t] * h + b[:, t]                    # (bb, bw)
        o_ref[:, t, :] = h.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, bs, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("bb", "bw", "bs", "interpret"))
def rglru_scan(x: jax.Array, gx: jax.Array, ga: jax.Array, log_a: jax.Array,
               h0: jax.Array, *, bb: int = 8, bw: int = 128, bs: int = 64,
               interpret: bool = False) -> jax.Array:
    """x/gx/ga (B, S, W); log_a (W,); h0 (B, W) -> h sequence (B, S, W)."""
    B, S, W = x.shape
    bb, bw, bs = min(bb, B), min(bw, W), min(bs, S)
    assert B % bb == 0 and W % bw == 0 and S % bs == 0
    kernel = functools.partial(_rglru_kernel, bs=bs, c=-8.0)
    blk = lambda ib, iw, it: (ib, it, iw)
    return pl.pallas_call(
        kernel,
        grid=(B // bb, W // bw, S // bs),
        in_specs=[
            pl.BlockSpec((bb, bs, bw), blk),
            pl.BlockSpec((bb, bs, bw), blk),
            pl.BlockSpec((bb, bs, bw), blk),
            pl.BlockSpec((1, bw), lambda ib, iw, it: (0, iw)),
            pl.BlockSpec((bb, bw), lambda ib, iw, it: (ib, iw)),
        ],
        out_specs=pl.BlockSpec((bb, bs, bw), blk),
        out_shape=jax.ShapeDtypeStruct((B, S, W), x.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bw), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, gx, ga, log_a.reshape(1, W), h0)
