"""The DSA systolic array as a Pallas TPU kernel.

The paper's accelerator is a 128x128 weight-stationary systolic array with
multi-bank scratchpads and a tiling compiler that double-buffers tile DMA
against tile compute (§IV-A).  On TPU this maps 1:1 onto the MXU with
explicit BlockSpec VMEM tiling: the (bm, bk) x (bk, bn) tiles stream through
VMEM while the grid pipeline overlaps the next tile's DMA with the current
tile's matmul — exactly the paper's "overlap memory transfer for a tile with
the computation of the preceding tile".

The paper's Vector Engine (activations / quantization / casting after the
GEMM) is fused into the epilogue on the last K step, so GEMM outputs never
round-trip to HBM — the paper's operator-fusion compiler pass.

Grid: (M/bm, N/bn, K/bk), K innermost (sequential accumulation into an fp32
VMEM scratch accumulator).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

_ACTS = {
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
}


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, act: str,
                   nk: int, out_dtype):
    """One (bm, bn) output tile; accumulate over the K grid dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU: fp32 accumulation of a (bm, bk) x (bk, bn) tile
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        if b_ref is not None:
            acc = acc + b_ref[...].astype(jnp.float32)
        acc = _ACTS[act](acc)
        o_ref[...] = acc.astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk",
                                             "out_dtype", "interpret"))
def systolic_matmul(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                    *, act: str = "none", bm: int = 128, bn: int = 128,
                    bk: int = 128, out_dtype=None,
                    interpret: bool = False) -> jax.Array:
    """(M, K) @ (K, N) [+ b] with fused epilogue.  Dims must tile evenly."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    out_dtype = out_dtype or x.dtype
    nk = K // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    args = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        args.append(b.reshape(1, N))

    kernel = functools.partial(
        _matmul_kernel if b is not None else
        (lambda x_ref, w_ref, o_ref, acc_ref, **kw:
         _matmul_kernel(x_ref, w_ref, None, o_ref, acc_ref, **kw)),
        act=act, nk=nk, out_dtype=out_dtype)

    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
