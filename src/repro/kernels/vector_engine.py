"""The DSA Vector Engine as Pallas kernels.

The paper's SIMD unit executes activation functions, quantization, datatype
casting and simple pre/post-processing after the systolic array (§IV-A).
On TPU these are VPU (8x128-lane) ops; we expose the three canonical
patterns:

  fused_affine_act : y = act(x * scale + bias), cast  (the GEMM epilogue /
                     normalization-style pre-processing)
  quantize_int8    : per-row symmetric int8 quantization (+ fp32 scales)
  dequantize_int8  : back to float
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.systolic_matmul import _ACTS


def _affine_kernel(x_ref, s_ref, b_ref, o_ref, *, act, out_dtype):
    x = x_ref[...].astype(jnp.float32)
    y = x * s_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    o_ref[...] = _ACTS[act](y).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("act", "out_dtype", "bm",
                                             "interpret"))
def fused_affine_act(x: jax.Array, scale: jax.Array, bias: jax.Array, *,
                     act: str = "none", out_dtype=None, bm: int = 256,
                     interpret: bool = False) -> jax.Array:
    """x (M, N); scale/bias (N,) broadcast per column."""
    M, N = x.shape
    bm = min(bm, M)
    assert M % bm == 0
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        functools.partial(_affine_kernel, act=act, out_dtype=out_dtype),
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((bm, N), lambda i: (i, 0)),
            pl.BlockSpec((1, N), lambda i: (0, 0)),
            pl.BlockSpec((1, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(x, scale.reshape(1, N), bias.reshape(1, N))


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def quantize_int8(x: jax.Array, *, bm: int = 256, interpret: bool = False):
    """x (M, N) -> (int8 (M, N), fp32 row scales (M, 1))."""
    M, N = x.shape
    bm = min(bm, M)
    assert M % bm == 0
    return pl.pallas_call(
        _quant_kernel,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, N), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, N), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, N), jnp.int8),
                   jax.ShapeDtypeStruct((M, 1), jnp.float32)],
        interpret=interpret,
    )(x)


def _dequant_kernel(q_ref, s_ref, o_ref, *, out_dtype):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "bm", "interpret"))
def dequantize_int8(q: jax.Array, scales: jax.Array, *, out_dtype=jnp.float32,
                    bm: int = 256, interpret: bool = False) -> jax.Array:
    M, N = q.shape
    bm = min(bm, M)
    assert M % bm == 0
    return pl.pallas_call(
        functools.partial(_dequant_kernel, out_dtype=out_dtype),
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, N), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(q, scales)
