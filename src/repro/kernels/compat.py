"""Version-compat shims for jax's Pallas TPU API.

jax renamed ``TPUCompilerParams`` to ``CompilerParams`` across releases;
every kernel routes through this helper so the next rename is one edit.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams", None)


def compiler_params(**kwargs):
    """Build the pallas-TPU compiler-params object for this jax version."""
    if _CLS is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; this jax version is unsupported")
    return _CLS(**kwargs)
