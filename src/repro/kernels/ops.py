"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the whole library (tests, smoke
runs, examples) exercises the kernel bodies on CPU; on a real TPU backend
the same calls compile to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels.systolic_matmul import systolic_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.vector_engine import (fused_affine_act, quantize_int8,
                                         dequantize_int8)
from repro.kernels.rglru import rglru_scan
from repro.kernels.ssd import ssd_scan
from repro.kernels.lindley import lindley_scan


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def matmul(x, w, b=None, *, act="none", bm=128, bn=128, bk=128,
           out_dtype=None, interpret=None):
    return systolic_matmul(x, w, b, act=act, bm=bm, bn=bn, bk=bk,
                           out_dtype=out_dtype,
                           interpret=_interpret_default()
                           if interpret is None else interpret)


def matmul_padded(x, w, b=None, *, act="none", bm=128, bn=128, bk=128,
                  out_dtype=None, interpret=None):
    """``matmul`` for arbitrary shapes: zero-pads (M, K, N) to tile
    multiples — the DSA compiler's padding pass (§V)."""
    import jax.numpy as jnp
    M, K = x.shape
    N = w.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    Mp = -(-M // bm) * bm
    Kp = -(-K // bk) * bk
    Np = -(-N // bn) * bn
    xp = jnp.pad(x, ((0, Mp - M), (0, Kp - K)))
    wp = jnp.pad(w, ((0, Kp - K), (0, Np - N)))
    bp = jnp.pad(b, (0, Np - N)) if b is not None else None
    out = matmul(xp, wp, bp, act=act, bm=bm, bn=bn, bk=bk,
                 out_dtype=out_dtype, interpret=interpret)
    return out[:M, :N]


def attention(q, k, v, *, causal=True, window=0, bq=128, bk=128,
              interpret=None):
    return flash_attention(q, k, v, causal=causal, window=window, bq=bq,
                           bk=bk, interpret=_interpret_default()
                           if interpret is None else interpret)


def affine_act(x, scale, bias, *, act="none", out_dtype=None, interpret=None):
    return fused_affine_act(x, scale, bias, act=act, out_dtype=out_dtype,
                            interpret=_interpret_default()
                            if interpret is None else interpret)


def quantize(x, *, interpret=None):
    return quantize_int8(x, interpret=_interpret_default()
                         if interpret is None else interpret)


def dequantize(q, scales, *, out_dtype=None, interpret=None):
    import jax.numpy as jnp
    return dequantize_int8(q, scales, out_dtype=out_dtype or jnp.float32,
                           interpret=_interpret_default()
                           if interpret is None else interpret)


def rglru(x, gx, ga, log_a, h0, *, interpret=None):
    return rglru_scan(x, gx, ga, log_a, h0, interpret=_interpret_default()
                      if interpret is None else interpret)


def ssd(x, dt, A, Bm, Cm, *, chunk=128, interpret=None):
    return ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                    interpret=_interpret_default()
                    if interpret is None else interpret)


def lindley(t, s, *, br=128, bd=128, interpret=None):
    """Batched FCFS service starts in float64 (queue-sim precision).

    x64 is enabled only for this call — the engine's byte-identity
    gates need exact fp64, but flipping the global default dtype would
    leak into every other kernel and model.
    """
    from jax.experimental import enable_x64
    with enable_x64():
        return lindley_scan(t, s, br=br, bd=bd,
                            interpret=_interpret_default()
                            if interpret is None else interpret)
