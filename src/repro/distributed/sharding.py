"""Logical-axis -> mesh-axis sharding rules.

Params carry *logical* axis names (see ``models.transformer.PDef``); this
module resolves them against a mesh with divisibility filtering so the same
rules work across all ten architectures (e.g. 40 heads don't divide a 16-way
model axis -> that dim falls back to replicated, while the flat H*Dh
projection dim still shards).

Rule sets:
  TRAIN_RULES : FSDP ("fsdp"->data) + TP ("tp"->model) + EP ("expert"->model)
  TP_RULES    : pure tensor parallel (no FSDP) — decode-latency friendly
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

TRAIN_RULES: Dict[str, Tuple[str, ...]] = {
    "vocab": ("model",),
    "fsdp": ("data",),
    "tp": ("model",),
    "expert": ("model",),
    "layer": (),
    "batch": ("pod", "data"),
    "cache_batch": ("pod", "data"),
    "cache_seq": ("model",),
    "heads": ("model",),
    "act_seq": (),            # sequence-parallel residual stream (off)
}

TP_RULES: Dict[str, Tuple[str, ...]] = dict(TRAIN_RULES, fsdp=())

# Sequence parallelism: residual-stream activations sharded over model along
# the sequence dim at block boundaries -> saved scan carries shrink 16x and
# TP all-reduces become reduce-scatter + all-gather pairs.
SEQPAR_RULES: Dict[str, Tuple[str, ...]] = dict(TRAIN_RULES,
                                                act_seq=("model",))

# Decode: weights 2D-RESIDENT (in-dim over data, out-dim over model) so no
# per-token FSDP weight gathers; the contraction over the data-sharded
# in-dim becomes a tiny (B,1,*) activation psum.  The KV cache keeps its
# ("pod","data") batch x "model" sequence sharding; activations reshard
# between the (batch-parallel) attention and (weight-parallel) FFN — a few
# hundred KB per layer at decode.
DECODE_RULES: Dict[str, Tuple[str, ...]] = dict(
    TRAIN_RULES, batch=("pod",), cache_batch=("pod", "data"),
    act_hidden=("data",),
)


def _fit_axes(dim: int, names: Sequence[str], mesh: Mesh) -> Tuple[str, ...]:
    """Longest prefix of mesh axes whose size product divides ``dim``."""
    out = []
    prod = 1
    for n in names:
        if n not in mesh.shape:
            continue
        sz = mesh.shape[n]
        if dim % (prod * sz) != 0:
            break
        out.append(n)
        prod *= sz
    return tuple(out)


def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             rules: Dict[str, Tuple[str, ...]], mesh: Mesh) -> P:
    used: set = set()
    parts = []
    for dim, ax in zip(shape, axes):
        if ax is None or ax not in rules:
            parts.append(None)
            continue
        cand = tuple(a for a in rules[ax] if a not in used)
        fit = _fit_axes(dim, cand, mesh)
        used.update(fit)
        if len(fit) == 0:
            parts.append(None)
        elif len(fit) == 1:
            parts.append(fit[0])
        else:
            parts.append(fit)
    # trim trailing Nones for tidiness
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_spec_tree(shape_tree: Pytree, axes_tree: Pytree,
                    rules: Dict[str, Tuple[str, ...]], mesh: Mesh) -> Pytree:
    # axes_tree leaves are tuples of logical names; shape_tree leaves have .shape
    flat_s, tdef = jax.tree.flatten(shape_tree, is_leaf=lambda x: hasattr(x, "shape"))
    flat_a = jax.tree.leaves(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    assert len(flat_s) == len(flat_a), (len(flat_s), len(flat_a))
    specs = [spec_for(s.shape, a, rules, mesh) for s, a in zip(flat_s, flat_a)]
    return tdef.unflatten(specs)


def batch_spec(shape: Tuple[int, ...], rules, mesh) -> P:
    """(B, ...) arrays: shard the leading batch dim."""
    fit = _fit_axes(shape[0], [a for a in rules.get("batch", ()) if a in mesh.shape],
                    mesh)
    if not fit:
        return P()
    return P(fit if len(fit) > 1 else fit[0])


def make_act_sharder(mesh: Mesh, rules) -> Callable[[jax.Array, str], jax.Array]:
    """Activation-constraint callback handed to the model code."""
    def shard(x: jax.Array, kind: str) -> jax.Array:
        if mesh.size == 1:
            return x
        parts: list = [None] * x.ndim
        used: set = set()
        bfit = _fit_axes(x.shape[0], [a for a in rules.get("batch", ())
                                      if a in mesh.shape], mesh)
        if bfit:
            parts[0] = bfit if len(bfit) > 1 else bfit[0]
            used.update(bfit)
        if kind == "act" and x.ndim == 3 and rules.get("act_seq"):
            # sequence parallelism at block boundaries
            sfit = _fit_axes(x.shape[1], tuple(a for a in rules["act_seq"]
                                               if a not in used), mesh)
            if sfit:
                parts[1] = sfit if len(sfit) > 1 else sfit[0]
                used.update(sfit)
        if kind == "act" and x.ndim == 3 and rules.get("act_hidden"):
            # hidden-dim-sharded residual stream (decode: weights stay
            # resident, contractions psum activation partials instead)
            hfit = _fit_axes(x.shape[-1], tuple(a for a in rules["act_hidden"]
                                                if a not in used), mesh)
            if hfit:
                parts[-1] = hfit if len(hfit) > 1 else hfit[0]
                used.update(hfit)
        if kind == "logits":
            vfit = _fit_axes(x.shape[-1], tuple(a for a in rules.get("vocab", ())
                                                if a not in used), mesh)
            if vfit:
                parts[-1] = vfit if len(vfit) > 1 else vfit[0]
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))

    shard.mesh = mesh      # model code (MoE EP path) reads these
    shard.rules = rules
    return shard
