"""Expert-parallel MoE via ``shard_map`` — the DSCS dispatch-to-data idea
applied to experts.

With tokens sharded over the data axes and *replicated* over the model axis,
each model-shard already holds every token; it simply selects the tokens
routed to its local experts, computes them, and contributes a partial output.
One ``psum`` over the model axis combines per-token expert outputs.  Per
layer that is a single activation-sized all-reduce — the same traffic as a
Megatron row-parallel FFN — instead of the token-table gathers/scatters that
sharding propagation produces for a gather-based MoE (measured: ~600x less
collective traffic on qwen3-moe-235b train_4k).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.layers import act_fn


def moe_ffn_ep(x: jax.Array, gate_w: jax.Array, w1: jax.Array, w3: jax.Array,
               w2: jax.Array, *, num_experts: int, k: int,
               capacity_factor: float, act: str, mesh: Mesh,
               batch_axes: Tuple[str, ...], ep_axis: str = "model"
               ) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (B, S, D), aux loss.  Experts sharded over ``ep_axis``."""
    E = num_experts
    ep = mesh.shape[ep_axis]
    assert E % ep == 0, (E, ep)
    E_loc = E // ep

    def body(xb, wgb, w1b, w3b, w2b):
        Bl, S, D = xb.shape
        T = Bl * S
        xf = xb.reshape(T, D)
        logits = jnp.einsum("td,de->te", xf, wgb.astype(xf.dtype)
                            ).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = lax.top_k(probs, k)                      # (T, k)
        topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)
        flat_e = topi.reshape(-1)                             # (T*k,)
        C = max(8, int(math.ceil(T * k * capacity_factor / E)))
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos_in_e = jnp.sum((jnp.cumsum(oh, axis=0) - 1) * oh, axis=-1)
        sid = lax.axis_index(ep_axis)
        own = (flat_e // E_loc) == sid
        keep = own & (pos_in_e < C)
        slot = jnp.where(keep, (flat_e % E_loc) * C + pos_in_e, E_loc * C)
        tok = jnp.repeat(jnp.arange(T), k)
        buf = jnp.zeros((E_loc * C + 1, D), xf.dtype).at[slot].set(xf[tok])
        xe = buf[: E_loc * C].reshape(E_loc, C, D)
        h = act_fn(act)(jnp.einsum("ecd,edf->ecf", xe, w1b))
        h = h * jnp.einsum("ecd,edf->ecf", xe, w3b)
        ye = jnp.einsum("ecf,efd->ecd", h, w2b)
        yflat = jnp.concatenate(
            [ye.reshape(E_loc * C, D), jnp.zeros((1, D), ye.dtype)], axis=0)
        wts = jnp.where(keep, topv.reshape(-1), 0.0).astype(yflat.dtype)
        yk = yflat[slot] * wts[:, None]                       # (T*k, D)
        out = jnp.sum(yk.reshape(T, k, D), axis=1)
        out = lax.psum(out, ep_axis)                          # combine shards
        # Switch-style load-balance aux (identical on every shard: logits
        # are computed from replicated x)
        me = probs.mean(axis=0)
        ce = jnp.bincount(flat_e, length=E).astype(jnp.float32) / (T * k)
        aux = E * jnp.sum(me * ce)
        return out.reshape(Bl, S, D), aux

    bspec = P(batch_axes if len(batch_axes) > 1 else
              (batch_axes[0] if batch_axes else None))
    specs = dict(in_specs=(bspec, P(), P(ep_axis), P(ep_axis), P(ep_axis)),
                 out_specs=(bspec, P()))
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map(body, mesh=mesh, check_vma=False, **specs)
    else:
        # older jax: shard_map lives in jax.experimental and the replication
        # check is spelled check_rep
        from jax.experimental.shard_map import shard_map as _shard_map
        fn = _shard_map(body, mesh=mesh, check_rep=False, **specs)
    return fn(x, gate_w, w1, w3, w2)


def _rank_in_expert(flat_e: jax.Array, num_experts: int) -> jax.Array:
    """Position of each routing decision within its expert's queue —
    sort-based (O(Tk log Tk) and O(Tk) memory) instead of the (Tk, E)
    one-hot cumsum (O(Tk*E) memory)."""
    n = flat_e.shape[0]
    perm = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[perm]
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts))
    pos_sorted = jnp.arange(n) - starts[sorted_e]
    return jnp.zeros((n,), jnp.int32).at[perm].set(pos_sorted.astype(jnp.int32))


def moe_ffn_ep_resident(x: jax.Array, gate_w: jax.Array, w1: jax.Array,
                        w3: jax.Array, w2: jax.Array, *, num_experts: int,
                        k: int, capacity_factor: float, act: str, mesh: Mesh,
                        batch_axes: Tuple[str, ...], ep_axis: str = "model",
                        fsdp_axis: str = "data") -> Tuple[jax.Array, jax.Array]:
    """Weight-RESIDENT expert parallelism (§Perf hillclimb, llama4 cell).

    Expert weights are 2D-sharded (experts over ``ep_axis``, hidden F over
    ``fsdp_axis``) and NEVER move.  Tokens all-gather over the data axis
    once per layer, each (data, model) device computes its experts' F-slice,
    partial outputs psum over data (F-combine) and over model (expert-
    combine) after slicing back to the local token block.  Replaces the
    per-layer expert-weight all-gathers (~weights/model bytes) with
    activation-sized collectives: measured ~6x collective reduction on
    llama4-maverick train_4k.
    """
    E = num_experts
    ep = mesh.shape[ep_axis]
    dp = mesh.shape[fsdp_axis]
    assert E % ep == 0
    E_loc = E // ep

    def body(xb, wgb, w1b, w3b, w2b):
        Bl, S, D = xb.shape
        T = Bl * S
        xf = xb.reshape(T, D)
        x_all = lax.all_gather(xf, fsdp_axis, axis=0, tiled=True)  # (T_all, D)
        T_all = T * dp
        logits = jnp.einsum("td,de->te", x_all,
                            wgb.astype(x_all.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = lax.top_k(probs, k)
        topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)
        flat_e = topi.reshape(-1)
        C = max(8, int(math.ceil(T_all * k * capacity_factor / E)))
        pos_in_e = _rank_in_expert(flat_e, E)
        sid = lax.axis_index(ep_axis)
        keep = ((flat_e // E_loc) == sid) & (pos_in_e < C)
        slot = jnp.where(keep, (flat_e % E_loc) * C + pos_in_e, E_loc * C)
        tok = jnp.repeat(jnp.arange(T_all), k)
        buf = jnp.zeros((E_loc * C + 1, D), xf.dtype).at[slot].set(x_all[tok])
        xe = buf[: E_loc * C].reshape(E_loc, C, D)
        h = act_fn(act)(jnp.einsum("ecd,edf->ecf", xe, w1b))
        h = h * jnp.einsum("ecd,edf->ecf", xe, w3b)     # (E_loc, C, F_loc)
        ye = jnp.einsum("ecf,efd->ecd", h, w2b)         # partial over F
        ye = lax.psum(ye, fsdp_axis)                    # F-combine (small)
        yflat = jnp.concatenate(
            [ye.reshape(E_loc * C, D), jnp.zeros((1, D), ye.dtype)], axis=0)
        wts = jnp.where(keep, topv.reshape(-1), 0.0)
        # combine only the local token block, THEN psum over experts
        did = lax.axis_index(fsdp_axis)
        myslot = lax.dynamic_slice(slot.reshape(T_all, k),
                                   (did * T, 0), (T, k))
        mywts = lax.dynamic_slice(wts.reshape(T_all, k),
                                  (did * T, 0), (T, k)).astype(yflat.dtype)
        yk = yflat[myslot.reshape(-1)] * mywts.reshape(-1)[:, None]
        out = jnp.sum(yk.reshape(T, k, D), axis=1)
        out = lax.psum(out, ep_axis)                    # expert-combine
        me = probs.mean(axis=0)
        ce = jnp.bincount(flat_e, length=E).astype(jnp.float32) / (T_all * k)
        aux = E * jnp.sum(me * ce)
        return out.reshape(Bl, S, D), aux

    bspec = P(batch_axes if len(batch_axes) > 1 else
              (batch_axes[0] if batch_axes else None))
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(bspec, P(), P(ep_axis, None, fsdp_axis),
                  P(ep_axis, None, fsdp_axis), P(ep_axis, fsdp_axis)),
        out_specs=(bspec, P()),
        check_vma=False,
    )
    return fn(x, gate_w, w1, w3, w2)
