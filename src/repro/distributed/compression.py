"""Int8 gradient compression with error feedback for the data-parallel
reduction (the classic 1-bit-Adam/TernGrad family, int8 variant).

At 1000+ node scale the cross-pod DP all-reduce is DCN-bound; quantizing
gradients to int8 (+ fp32 per-leaf scale) cuts wire bytes 4x vs fp32 /
2x vs bf16.  Error feedback keeps the quantization *unbiased over time*:
the residual e_t is added back before the next quantization, so SGD/Adam
convergence is preserved (measured: `tests/test_compression.py` trains to
the same loss +-2%).

The compress -> (reduce) -> decompress pipeline is expressed functionally;
on hardware the int8 payload is what crosses the DCN.  The vector-engine
Pallas kernel (`kernels.vector_engine.quantize_int8`) is the on-device
implementation of the same transform.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def _quantize_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def init_error_state(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Pytree, error: Pytree) -> Tuple[Pytree, Pytree]:
    """grads + carried error -> (dequantized int8 grads, new error).

    The returned grads are exactly what a receiver of the int8 payload
    would reconstruct; ``new_error`` is the residual to feed back next step.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize_leaf(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    out = jax.tree.map(one, grads, error)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def wire_bytes(params: Pytree, dtype_bytes: int = 4) -> Tuple[int, int]:
    """(uncompressed, compressed) DP-reduction payload sizes in bytes."""
    import numpy as np
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    leaves = len(jax.tree.leaves(params))
    return n * dtype_bytes, n * 1 + leaves * 4
