"""Reproduce the paper's Fig. 7 design-space exploration.

    PYTHONPATH=src python examples/dse_explore.py

Sweeps PE array / scratchpad / memory-technology configurations, extracts
the power<->throughput Pareto frontier under the CSD power cap, and prints
both the paper's (square-array) winner and the beyond-paper rectangular
optimum.
"""
from repro.core.dsa import DSAConfig
from repro.core.dse import (DSA_POWER_CAP_W, evaluate, optimal_design,
                            optimal_square_design, pareto, sweep)


def main():
    pts = sweep()
    feas = [p for p in pts if p.feasible]
    print(f"swept {len(pts)} configurations, {len(feas)} feasible "
          f"under the {DSA_POWER_CAP_W:.0f} W DSA budget")
    front = pareto(feas, "power_w")
    print("\npower <-> throughput Pareto frontier:")
    for p in front:
        print(f"  {p.cfg.name:24s} {p.throughput_fps:7.1f} fps  "
              f"{p.power_w:6.2f} W  {p.area_mm2:6.1f} mm^2")
    sq = optimal_square_design(pts)
    best = optimal_design(pts)
    paper = evaluate(DSAConfig())
    print(f"\nsquare-array winner (paper's search space): {sq.cfg.name} "
          f"@ {sq.power_w:.2f} W")
    print(f"paper's point 128x128/4MB/DDR5: {paper.throughput_fps:.1f} fps "
          f"@ {paper.power_w:.2f} W (paper says 4.2 W)")
    print(f"beyond-paper rectangular winner: {best.cfg.name} "
          f"({best.throughput_fps:.1f} fps @ {best.power_w:.2f} W)")


if __name__ == "__main__":
    main()
