"""Serving driver: batched requests through prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_pipeline.py [--arch mamba2-370m]

The DSCS analogy end-to-end: requests land on the data-shard that owns
their payload; decode steps run where the KV cache/SSM state lives.
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    out = serve(args.arch, smoke=True, batch=args.batch, prompt=args.prompt,
                gen=args.gen)
    print(f"generated tokens:\n{out['generated']}")
    print(f"prefill {out['prefill_s'] * 1e3:.0f} ms, "
          f"decode {out['decode_s_per_token'] * 1e3:.1f} ms/token")


if __name__ == "__main__":
    main()
