"""Quickstart: run one serverless ML pipeline end-to-end on the DSCS model.

    PYTHONPATH=src python examples/quickstart.py

Executes the paper's Fig. 2 three-function pipeline (pre-process -> ResNet
inference -> notify) numerically on JAX — the DSA path runs the Pallas
systolic/vector-engine kernels — and prints the latency & energy breakdown
vs the traditional CPU deployment.
"""
import jax

from repro.core.executor import DSCSExecutor


def main():
    key = jax.random.PRNGKey(0)
    for platform in ("Baseline-CPU", "DSCS-Serverless"):
        ex = DSCSExecutor("asset_damage", platform=platform, image_size=64)
        rep = ex(ex.make_request(key))
        bd = rep.latency_breakdown
        print(f"\n=== {platform} ===")
        print(f"  predicted class: {int(rep.result[0])}")
        for k in ("stack", "net", "io", "compute", "driver"):
            print(f"  {k:8s} {bd[k] * 1e3:8.2f} ms  ({bd[k] / bd['total']:5.1%})")
        print(f"  {'total':8s} {bd['total'] * 1e3:8.2f} ms"
              f"   energy {rep.energy_breakdown['total']:.2f} J")
    print("\nDSCS removes the network round-trips for f1/f2 — the paper's "
          "core observation.")


if __name__ == "__main__":
    main()
