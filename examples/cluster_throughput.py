"""Fig. 12 analogue: throughput of a DSCS drive fleet vs a CPU fleet under a
99% SLA, via the event-driven cluster simulator (FCFS, fallback, Poisson).

    PYTHONPATH=src python examples/cluster_throughput.py
"""
from repro.core.function import standard_pipeline
from repro.core.scheduler import ClusterSim


def main():
    names = ("asset_damage", "content_moderation", "credit_risk")
    pipes = [standard_pipeline(n) for n in names]
    pipes_cpu = [standard_pipeline(n, accelerate=False) for n in names]
    dscs = ClusterSim(n_dscs=100, n_cpu=100, seed=0).max_throughput(
        pipes, sla_s=0.6, duration_s=20)
    cpu = ClusterSim(n_dscs=0, n_cpu=100, seed=0).max_throughput(
        pipes_cpu, sla_s=0.6, duration_s=20)
    print(f"DSCS fleet : {dscs:7.1f} req/s @ 99% <= 600 ms")
    print(f"CPU fleet  : {cpu:7.1f} req/s")
    print(f"ratio      : {dscs / cpu:.2f}x   (paper Fig. 12: 3.1x)")


if __name__ == "__main__":
    main()
