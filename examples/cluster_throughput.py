"""Fleet-level scenarios on the discrete-event cluster engine.

1. Fig. 12 analogue — throughput of a DSCS drive fleet vs a CPU fleet
   under a 99% SLA (FCFS per drive, data-aware placement, Poisson load).
2. Arrival-shape sweep — the same SLA search under bursty (MMPP) and
   diurnal load.
3. Fig. 16 analogue — hedged dispatch: p99 under bursty load with the
   hedge timer off vs on.

    PYTHONPATH=src python examples/cluster_throughput.py
"""
import numpy as np

from repro.core.arrivals import BurstyOnOff, make_arrivals
from repro.core.function import standard_pipeline
from repro.core.scheduler import ClusterSim


def main():
    names = ("asset_damage", "content_moderation", "credit_risk")
    pipes = [standard_pipeline(n) for n in names]
    pipes_cpu = [standard_pipeline(n, accelerate=False) for n in names]

    dscs = ClusterSim(n_dscs=100, n_cpu=100, seed=0).max_throughput(
        pipes, sla_s=0.6, duration_s=20)
    cpu = ClusterSim(n_dscs=0, n_cpu=100, seed=0).max_throughput(
        pipes_cpu, sla_s=0.6, duration_s=20)
    print(f"DSCS fleet : {dscs:7.1f} req/s @ 99% <= 600 ms")
    print(f"CPU fleet  : {cpu:7.1f} req/s")
    print(f"ratio      : {dscs / cpu:.2f}x   (paper Fig. 12: 3.1x)")

    print("\narrival-shape sweep (20 DSCS + 20 CPU, 99% <= 600 ms):")
    for kind in ("poisson", "bursty", "diurnal"):
        rps = ClusterSim(n_dscs=20, n_cpu=20, seed=0).max_throughput(
            pipes, sla_s=0.6, duration_s=10, hi=2048.0,
            arrivals=make_arrivals(kind, 1.0))
        print(f"  {kind:8s}: {rps:7.1f} req/s")

    print("\nhedged dispatch under bursty load (6 DSCS + 24 CPU):")
    arr = BurstyOnOff(rate=120.0, burst_factor=5.0, mean_on_s=1.0,
                      mean_off_s=4.0)
    for label, budget in (("off", None), ("on ", 0.1)):
        sim = ClusterSim(n_dscs=6, n_cpu=24, hedge_budget_s=budget, seed=0)
        res = sim.run([standard_pipeline("content_moderation")],
                      arrivals=arr, duration_s=30)
        lat = np.array([r.latency for r in res])
        hedged = sum(r.hedged for r in res)
        q = sim.queue_stats()
        print(f"  hedge {label}: p50={np.percentile(lat, 50) * 1e3:7.1f} ms  "
              f"p99={np.percentile(lat, 99) * 1e3:7.1f} ms  "
              f"hedged={hedged:4d}  "
              f"max drive queue={q['dscs']['max_depth']:.0f}")


if __name__ == "__main__":
    main()
