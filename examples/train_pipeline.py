"""End-to-end training driver: train a (reduced) assigned architecture for a
few hundred steps with checkpoint/restart.

    PYTHONPATH=src python examples/train_pipeline.py [--arch qwen3-8b] [--steps 200]

Demonstrates the full production loop on CPU: sharded init on the local
mesh, deterministic resumable data, AdamW + cosine schedule, atomic
checkpoints, crash-resume (`--resume`).
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    losses = train(args.arch, smoke=True, steps=args.steps, batch=8, seq=128,
                   ckpt_dir="/tmp/repro_example_ckpt", resume=args.resume,
                   checkpoint_every=50, log_every=20)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")


if __name__ == "__main__":
    main()
