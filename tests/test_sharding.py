"""Differential shard-equivalence harness for the sharded fleet engine.

The central contract of :mod:`repro.core.sharding`'s partitioned fast
path is *shard-count independence*: for any two shard counts (and any
process count) the same configuration must produce byte-identical
traces, telemetry, queue areas and busy-seconds — sharding is an
execution strategy, never a model change.  A seeded config generator
sweeps fleet shape x arrival process x hedge / tier / fault / timeout
toggles and asserts exactly that; runs that route through the classic
per-shard event loop (faults / tiering / deadlines) are additionally
checked for conservation and consistent merged bookkeeping.  The
``n_shards=1`` path must replay the committed golden traces
byte-for-byte, and on an uncongested fleet the partitioned math must be
bit-equal to the classic engine column-for-column.
"""
import json
import math
import pathlib

import numpy as np
import pytest

from repro.core.arrivals import BurstyOnOff, DiurnalProcess, PoissonProcess
from repro.core.engine import ClusterEngine
from repro.core.faults import ExponentialBackoff, FaultPlan, RepairModel
from repro.core.function import standard_pipeline
from repro.core.scheduler import ClusterSim
from repro.core.sharding import (MailboxOverflow, ShardPlan, cpu_affinity,
                                 run_partitioned)
from repro.core.tiering import TierConfig

GOLDEN = pathlib.Path(__file__).parent / "golden"
PIPES = [standard_pipeline(n) for n in ("asset_damage", "content_moderation")]
MIXED = PIPES + [standard_pipeline("asset_damage", accelerate=False)]
COLUMNS = ("arrival", "finish", "winner", "drive", "start", "service",
           "hedged", "dscs_finish", "cpu_finish")


def make_config(seed: int) -> dict:
    """Seeded config generator: fleet shape x arrival process x
    hedge / tier / fault / timeout toggles."""
    rng = np.random.default_rng(seed)
    n_dscs = int(rng.choice([4, 8, 12, 16]))
    n_cpu = int(rng.choice([n_dscs, n_dscs // 2 + 2, 2 * n_dscs]))
    rate = float(rng.uniform(80.0, 400.0))
    kind = rng.choice(["poisson", "bursty", "diurnal"])
    if kind == "poisson":
        arrivals = PoissonProcess(rate=rate)
    elif kind == "bursty":
        arrivals = BurstyOnOff(rate=rate, burst_factor=3.0)
    else:
        arrivals = DiurnalProcess(rate=rate, amplitude=0.6, period_s=4.0)
    return {
        "n_dscs": n_dscs, "n_cpu": n_cpu, "arrivals": arrivals,
        "duration_s": float(rng.uniform(2.0, 5.0)),
        "hedge": (None if rng.random() < 0.3
                  else float(rng.uniform(0.02, 0.15))),
        "pipes": MIXED if rng.random() < 0.5 else PIPES,
        "tier": (TierConfig(replication_k=2, n_objects=64)
                 if rng.random() < 0.35 else None),
        "faults": (FaultPlan(drive_mtbf_s=4.0, drive_mttr_s=1.5,
                             retry=ExponentialBackoff(base_s=0.05),
                             repair=RepairModel())
                   if rng.random() < 0.35 else None),
        "timeout_s": float(rng.uniform(1.0, 3.0)) if rng.random() < 0.3
                     else None,
        "seed": int(rng.integers(1 << 16)),
    }


def run_cfg(cfg: dict, n_shards: int, processes: int = 1,
            backend: str = "segmented"):
    eng = ClusterEngine(n_dscs=cfg["n_dscs"], n_cpu=cfg["n_cpu"],
                        hedge_budget_s=cfg["hedge"], seed=cfg["seed"],
                        tier=cfg["tier"], faults=cfg["faults"])
    tr = eng.run_sharded(cfg["pipes"], arrivals=cfg["arrivals"],
                         duration_s=cfg["duration_s"], n_shards=n_shards,
                         processes=processes, timeout_s=cfg["timeout_s"],
                         backend=backend)
    return eng, tr


def assert_traces_identical(a, b) -> None:
    for col in COLUMNS:
        assert getattr(a, col).tobytes() == getattr(b, col).tobytes(), col
    assert a.events == b.events


# --------------------------------------------------------------------------
# the differential harness: shard-count / process-count independence
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(12))
def test_sharded_runs_are_shard_count_independent(seed):
    """n_shards=2 and n_shards=4 must agree on every per-request column
    and every aggregate (completions, busy-seconds, queue-depth areas,
    fault/tier counters) — byte-for-byte on the partitioned path,
    aggregate-exact on the shard-isolated fallback."""
    cfg = make_config(seed)
    if cfg["n_dscs"] < 4 or cfg["n_cpu"] < 4:
        pytest.skip("fleet too small for 4 shards")
    e2, t2 = run_cfg(cfg, 2)
    e4, t4 = run_cfg(cfg, 4)
    pure = e2.last_shard_stats["path"] == "partitioned"
    assert pure == (e4.last_shard_stats["path"] == "partitioned")
    if pure:
        # partitioned semantics: the shard count can never change a bit
        assert_traces_identical(t2, t4)
        assert e2._qstate == e4._qstate
        assert e2._pstate == e4._pstate
        assert dict(e2.telemetry.counters) == dict(e4.telemetry.counters)
    else:
        # shard-isolated classic loops: per-request streams are defined
        # by the k-partition, but conservation and the merged books must
        # agree with the per-request columns under every k
        for eng, tr in ((e2, t2), (e4, t4)):
            completed = int(tr.completed.sum())
            abandoned = int((tr.winner == -1).sum())
            assert completed + abandoned == tr.n
            fs = eng.fault_stats()
            if fs is not None:
                assert fs["goodput"]["offered"] == tr.n
                assert fs["goodput"]["completed"] == completed
        assert t2.n == t4.n
        assert np.array_equal(t2.arrival, t4.arrival)


@pytest.mark.parametrize("seed", range(6))
def test_lindley_backends_are_bit_identical(seed):
    """The dense (legacy padded) and segmented (bucketed) Lindley
    solvers must produce byte-identical traces, queue state and
    telemetry on the partitioned fast path — backend choice is an
    execution strategy, never a model change."""
    cfg = {**make_config(seed), "tier": None, "faults": None,
           "timeout_s": None}
    es, ts = run_cfg(cfg, 2, backend="segmented")
    assert es.last_shard_stats["path"] == "partitioned"
    ed, td = run_cfg(cfg, 2, backend="dense")
    assert_traces_identical(ts, td)
    assert es._qstate == ed._qstate
    assert es._pstate == ed._pstate
    assert dict(es.telemetry.counters) == dict(ed.telemetry.counters)


def test_pallas_backend_is_bit_identical():
    """Interpret-mode Pallas solve of a whole sharded run matches the
    segmented numpy backend byte-for-byte (small config: interpret mode
    trades speed for exactness)."""
    cfg = {**make_config(1), "tier": None, "faults": None,
           "timeout_s": None, "duration_s": 1.0}
    es, ts = run_cfg(cfg, 2, backend="segmented")
    assert es.last_shard_stats["path"] == "partitioned"
    ep, tp = run_cfg(cfg, 2, backend="pallas")
    assert_traces_identical(ts, tp)
    assert es._qstate == ep._qstate


def test_unknown_backend_is_rejected():
    cfg = {**make_config(0), "tier": None, "faults": None,
           "timeout_s": None}
    with pytest.raises(ValueError, match="backend"):
        run_cfg(cfg, 2, backend="flat")


@pytest.mark.parametrize("seed", [0, 3, 5, 8])
def test_sharded_runs_are_process_count_independent(seed):
    """Serial in-process execution and a forked worker pool must produce
    byte-identical traces and identical merged stats."""
    cfg = make_config(seed)
    e1, t1 = run_cfg(cfg, 2, processes=1)
    e2, t2 = run_cfg(cfg, 2, processes=2)
    assert_traces_identical(t1, t2)
    assert e1._qstate == e2._qstate
    assert e1._pstate == e2._pstate
    assert e1._fstate == e2._fstate
    assert e1._tierstate == e2._tierstate
    assert dict(e1.telemetry.counters) == dict(e2.telemetry.counters)


def test_sharded_rerun_is_deterministic():
    cfg = make_config(2)
    _, a = run_cfg(cfg, 2)
    _, b = run_cfg(cfg, 2)
    assert_traces_identical(a, b)


# --------------------------------------------------------------------------
# n_shards=1: the classic loop, golden byte-for-byte
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [13, 21])
def test_single_shard_replays_golden_trace(seed):
    """run_sharded(n_shards=1) IS the classic engine: it must replay the
    committed golden traces field-for-field (float equality, all
    columns)."""
    golden = json.loads((GOLDEN / f"engine_trace_seed{seed}.json").read_text())
    cfg = golden["config"]
    eng = ClusterEngine(n_dscs=cfg["n_dscs"], n_cpu=cfg["n_cpu"],
                        hedge_budget_s=cfg["hedge_budget_s"],
                        seed=cfg["seed"])
    tr = eng.run_sharded([standard_pipeline(n) for n in cfg["pipelines"]],
                         arrivals=PoissonProcess(rate=cfg["rate"]),
                         duration_s=cfg["duration_s"], n_shards=1)
    assert tr.n == golden["n"]
    for i, (r, row) in enumerate(zip(tr.to_results(), golden["results"])):
        got = [r.arrival, r.finish, r.accelerated, r.hedged, r.winner,
               r.drive, r.start, r.service, r.dscs_finish, r.cpu_finish]
        assert got == row, f"request {i} deviates from the pinned trace"


def test_single_shard_matches_run_soa_exactly():
    ea = ClusterEngine(n_dscs=4, n_cpu=8, hedge_budget_s=0.05, seed=9)
    a = ea.run_soa(PIPES, arrivals=PoissonProcess(rate=90.0), duration_s=6.0)
    eb = ClusterEngine(n_dscs=4, n_cpu=8, hedge_budget_s=0.05, seed=9)
    b = eb.run_sharded(PIPES, arrivals=PoissonProcess(rate=90.0),
                       duration_s=6.0, n_shards=1)
    assert_traces_identical(a, b)
    assert ea._qstate == eb._qstate


# --------------------------------------------------------------------------
# partitioned math vs the classic event loop
# --------------------------------------------------------------------------

def test_uncongested_fleet_is_bit_equal_to_classic():
    """With arrivals spaced far apart no queueing ever happens, so the
    classic engine consumes its service draws in request order and both
    models start every copy at its arrival: all columns bit-equal."""
    times = np.arange(200, dtype=np.float64) * 10.0
    e1 = ClusterEngine(n_dscs=4, n_cpu=4, hedge_budget_s=None, seed=3)
    t1 = e1.run_soa(PIPES, times=times)
    e2 = ClusterEngine(n_dscs=4, n_cpu=4, hedge_budget_s=None, seed=3)
    t2 = e2.run_sharded(PIPES, times=times, n_shards=2)
    assert_traces_identical(t1, t2)


def test_sharded_run_simulates_the_same_workload_as_classic():
    """Sharded runs draw the same arrival stream and pipeline picks as
    the classic engine (SeedSequence children 0/1), and route on the
    same placement hash — only queueing dynamics may differ."""
    e1 = ClusterEngine(n_dscs=8, n_cpu=8, hedge_budget_s=0.05, seed=5)
    t1 = e1.run_soa(MIXED, arrivals=PoissonProcess(rate=300.0),
                    duration_s=4.0)
    e2 = ClusterEngine(n_dscs=8, n_cpu=8, hedge_budget_s=0.05, seed=5)
    t2 = e2.run_sharded(MIXED, arrivals=PoissonProcess(rate=300.0),
                        duration_s=4.0, n_shards=2)
    assert np.array_equal(t1.arrival, t2.arrival)
    # accelerated requests carry a dscs_finish in both models; their
    # drive assignment is the same placement hash whenever DSCS wins
    assert np.array_equal(np.isnan(t1.dscs_finish), np.isnan(t2.dscs_finish))
    both_dscs = (t1.winner == 0) & (t2.winner == 0)
    assert np.array_equal(t1.drive[both_dscs], t2.drive[both_dscs])
    assert int(t1.completed.sum()) == int(t2.completed.sum()) == t1.n


# --------------------------------------------------------------------------
# partition plan and mailbox semantics
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n_dscs,n_cpu,k", [(8, 8, 2), (12, 7, 3), (9, 4, 4),
                                            (16, 33, 5), (5, 5, 5)])
def test_shard_plan_partitions_the_fleet(n_dscs, n_cpu, k):
    plan = ShardPlan.build(n_dscs, n_cpu, k, seed=1)
    assert plan.drive_bounds[0] == 0 and plan.drive_bounds[-1] == n_dscs
    assert plan.cpu_bounds[0] == 0 and plan.cpu_bounds[-1] == n_cpu
    for s in range(k):
        assert plan.drive_bounds[s + 1] > plan.drive_bounds[s]
        assert plan.cpu_bounds[s + 1] > plan.cpu_bounds[s]
    assert len(set(plan.shard_seeds)) == k
    # stable: rebuilding with more shards never changes earlier seeds
    if k > 2:
        sub = ShardPlan.build(n_dscs, n_cpu, 2, seed=1)
        assert sub.shard_seeds == plan.shard_seeds[:2]
    drives = np.arange(n_dscs)
    owner = plan.shard_of_drive(drives)
    assert owner.min() == 0 and owner.max() == k - 1


def test_shard_plan_rejects_oversharding():
    with pytest.raises(ValueError):
        ShardPlan.build(2, 8, 3, seed=0)
    with pytest.raises(ValueError):
        ShardPlan.build(8, 2, 3, seed=0)


def test_matched_fleet_has_no_cross_shard_traffic():
    """With n_cpu == n_dscs every drive's CPU block is its own shard's
    slice, so all hedge/CPU traffic stays shard-local."""
    eng, _ = run_cfg({"n_dscs": 8, "n_cpu": 8,
                      "arrivals": PoissonProcess(rate=300.0),
                      "duration_s": 4.0, "hedge": 0.05, "pipes": MIXED,
                      "tier": None, "faults": None, "timeout_s": None,
                      "seed": 4}, 4)
    mb = eng.last_shard_stats["mailbox"]
    assert mb["posted"] > 0
    assert mb["cross_shard"] == 0
    assert eng.last_shard_stats["cross_shard_hedges"] == 0


def test_mismatched_fleet_counts_cpu_spillover():
    """Drive blocks that straddle a CPU fencepost produce genuine
    cross-shard mailbox traffic."""
    eng, _ = run_cfg({"n_dscs": 12, "n_cpu": 5,
                      "arrivals": PoissonProcess(rate=300.0),
                      "duration_s": 4.0, "hedge": 0.03, "pipes": MIXED,
                      "tier": None, "faults": None, "timeout_s": None,
                      "seed": 4}, 3)
    assert eng.last_shard_stats["mailbox"]["cross_shard"] > 0


def test_mailbox_capacity_bounds_outstanding_messages():
    eng = ClusterEngine(n_dscs=8, n_cpu=8, hedge_budget_s=0.02, seed=4)
    with pytest.raises(MailboxOverflow):
        eng.run_sharded(MIXED, arrivals=PoissonProcess(rate=400.0),
                        duration_s=4.0, n_shards=2, mailbox_capacity=3)


def test_cpu_affinity_is_fleet_shape_pure():
    a = cpu_affinity(8, 8, 500)
    b = cpu_affinity(8, 8, 500)
    assert np.array_equal(a, b)
    assert a.min() >= 0 and a.max() < 8
    # more drives than CPU nodes: still a valid node for every request
    c = cpu_affinity(16, 3, 500)
    assert c.min() >= 0 and c.max() < 3


# --------------------------------------------------------------------------
# shard-isolated fallback bookkeeping
# --------------------------------------------------------------------------

def test_fallback_merges_fault_and_tier_books():
    cfg = {"n_dscs": 8, "n_cpu": 8, "arrivals": PoissonProcess(rate=250.0),
           "duration_s": 4.0, "hedge": 0.05, "pipes": PIPES,
           "tier": TierConfig(replication_k=2, n_objects=64),
           "faults": FaultPlan(drive_mtbf_s=3.0, drive_mttr_s=1.0,
                               retry=ExponentialBackoff(base_s=0.05),
                               repair=RepairModel()),
           "timeout_s": 2.5, "seed": 17}
    eng, tr = run_cfg(cfg, 2)
    assert eng.last_shard_stats["path"] == "shard-isolated"
    fs = eng.fault_stats()
    assert fs["enabled"]
    assert fs["goodput"]["offered"] == tr.n
    assert len(fs["unavailability"]["per_drive_s"]) == 8
    ts = eng.tier_stats()
    assert ts["replication_k"] == 2
    assert len(ts["cache"]["per_drive"]) in (0, 8)
    completed = int(tr.completed.sum())
    abandoned = int((tr.winner == -1).sum())
    assert completed + abandoned == tr.n
    # drive indices were remapped into the global fleet range
    served = tr.drive[tr.drive >= 0]
    assert served.size and served.max() < 8
    ps = eng.power_stats()
    horizon = eng._qstate["horizon"]
    assert ps["dscs"]["busy_s"] <= 8 * horizon + 1e-9
    assert ps["cpu"]["busy_s"] <= 8 * horizon + 1e-9


def test_fallback_timeout_only_goodput():
    cfg = {"n_dscs": 4, "n_cpu": 4, "arrivals": PoissonProcess(rate=500.0),
           "duration_s": 3.0, "hedge": None, "pipes": PIPES, "tier": None,
           "faults": None, "timeout_s": 0.4, "seed": 6}
    eng, tr = run_cfg(cfg, 2)
    fs = eng.fault_stats()
    assert fs is not None and not fs["enabled"]
    assert fs["deadline_abandoned"] == int((tr.winner == -1).sum())
    assert fs["goodput"]["completed"] == int(tr.completed.sum())


def test_fallback_warns_when_backend_is_ignored():
    """ISSUE 10 satellite: fallback runs (faults/tiering/deadline/overload)
    never reach the Lindley fast path, so a non-default ``backend=`` is a
    no-op — the engine must say so instead of silently ignoring it."""
    import warnings
    eng = ClusterEngine(n_dscs=4, n_cpu=4, seed=2,
                        faults=FaultPlan(drive_mtbf_s=5.0, drive_mttr_s=1.0))
    with pytest.warns(UserWarning, match="backend='pallas' has no effect"):
        eng.run_sharded(PIPES, arrivals=PoissonProcess(rate=50.0),
                        duration_s=2.0, n_shards=2, backend="pallas")
    # the default backend name stays silent on the same fallback run
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng.run_sharded(PIPES, arrivals=PoissonProcess(rate=50.0),
                        duration_s=2.0, n_shards=2, backend="segmented")


def test_tiny_run_with_empty_shards():
    """A shard that owns zero requests must not break the merge."""
    times = np.array([0.0, 0.01, 0.02])
    eng = ClusterEngine(n_dscs=8, n_cpu=8, hedge_budget_s=0.05, seed=1,
                        faults=FaultPlan(drive_mtbf_s=50.0, drive_mttr_s=1.0))
    tr = eng.run_sharded(PIPES, times=times, n_shards=4, timeout_s=5.0)
    assert tr.n == 3
    assert int(tr.completed.sum()) + int((tr.winner == -1).sum()) == 3


def test_empty_arrival_stream():
    eng = ClusterEngine(n_dscs=4, n_cpu=4, hedge_budget_s=0.05, seed=1)
    tr = eng.run_sharded(PIPES, times=np.empty(0), n_shards=2)
    assert tr.n == 0


# --------------------------------------------------------------------------
# guard rails
# --------------------------------------------------------------------------

def test_sharded_requires_pipelines():
    eng = ClusterEngine(n_dscs=4, n_cpu=4, seed=0)
    with pytest.raises(ValueError):
        eng.run_sharded(None, arrivals=PoissonProcess(rate=10.0),
                        duration_s=1.0, n_shards=2)


def test_facade_run_sharded_matches_engine():
    sim = ClusterSim(n_dscs=8, n_cpu=8, hedge_budget_s=0.05, seed=7)
    tr = sim.run_sharded(PIPES, rps=200.0, duration_s=3.0, n_shards=2)
    eng = ClusterEngine(n_dscs=8, n_cpu=8, hedge_budget_s=0.05, seed=7)
    tr2 = eng.run_sharded(PIPES, arrivals=PoissonProcess(rate=200.0),
                          duration_s=3.0, n_shards=2)
    assert_traces_identical(tr, tr2)
    assert sim.queue_stats()["dscs"]["max_depth"] >= 1.0
