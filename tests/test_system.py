"""End-to-end behaviour tests for the whole system."""
import glob
import json
import os

import jax
import numpy as np
import pytest


@pytest.mark.slow
def test_training_reduces_loss(tmp_path):
    """~100M-class family member (reduced) trains: loss must drop."""
    from repro.launch.train import train
    losses = train("qwen3-8b", smoke=True, steps=15, batch=4, seq=64,
                   ckpt_dir=str(tmp_path), checkpoint_every=100, log_every=100)
    assert losses[-1] < losses[0] - 0.3


@pytest.mark.slow
def test_serving_generates(tmp_path):
    from repro.launch.serve import serve
    out = serve("qwen1.5-4b", smoke=True, batch=2, prompt=16, gen=4)
    assert out["generated"].shape == (2, 4)
    assert out["generated"].dtype == np.int32


@pytest.mark.slow
def test_serving_ssm_generates():
    from repro.launch.serve import serve
    out = serve("mamba2-370m", smoke=True, batch=2, prompt=16, gen=4)
    assert out["generated"].shape == (2, 4)


@pytest.mark.slow
def test_dscs_pipeline_end_to_end():
    """The paper's Fig. 2 flow executes numerically with kernels engaged."""
    from repro.core.executor import DSCSExecutor
    ex = DSCSExecutor("asset_damage", platform="DSCS-Serverless",
                      image_size=32)
    rep = ex(ex.make_request(jax.random.PRNGKey(0)))
    assert rep.accelerated
    assert rep.result.shape == (1,)
    bd = rep.latency_breakdown
    # near-storage: no network for f1/f2 intermediates — only f3's read
    assert bd["net"] < bd["total"] * 0.6


def test_dryrun_records_complete_and_coherent():
    """Every (arch x shape x mesh) cell has a record; ok cells carry
    memory/cost/roofline; skips are only long_500k x quadratic archs."""
    from repro.configs import cells
    files = glob.glob("results/dryrun/*.json")
    if not files:
        pytest.skip("dry-run results not present in this checkout")
    recs = {(r["arch"], r["shape"], r["mesh"]): r
            for r in (json.load(open(f)) for f in files)}
    want = [(a.name, s.name, m) for a, s, _ in cells()
            for m in ("single", "multi")]
    missing = [w for w in want if w not in recs]
    assert not missing, missing[:5]
    for key, r in recs.items():
        assert r["status"] in ("ok", "skipped"), (key, r.get("error"))
        if r["status"] == "skipped":
            assert r["shape"] == "long_500k"
        else:
            assert r["memory"]["peak_bytes"] > 0
            t = r["roofline"]
            assert t["flops_per_chip"] > 0
            assert t["dominant"] in ("compute", "memory", "collective")


def test_dryrun_flop_accounting_sane():
    """Corrected HLO FLOPs within sane multiples of MODEL_FLOPS."""
    files = glob.glob("results/dryrun/*__train_4k__multi__train.json")
    if not files:
        pytest.skip("dry-run results not present")
    for f in files:
        r = json.load(open(f))
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        hlo_total = t["flops_per_chip"] * t["chips"]
        # train: fwd+bwd+remat ~ 8/6 x MODEL_FLOPS; allow dispatch overheads
        ratio = hlo_total / t["model_flops_total"]
        assert 0.9 < ratio < 12.0, (r["arch"], ratio)
