"""DSCS core: latency/energy/cost models, DSE, scheduler, placement,
executor — plus validation of the paper's headline claims (tolerances
documented in EXPERIMENTS.md §Paper-validation)."""
import numpy as np
import pytest

from repro.core.cost import cost_efficiency_vs_baseline
from repro.core.dsa import DSAConfig, dsa_power_w, gemm_cycles, GemmShape
from repro.core.dse import (DSA_POWER_CAP_W, evaluate, optimal_design,
                            optimal_square_design, pareto, sweep)
from repro.core.energy import energy_reduction_vs_baseline
from repro.core.executor import DSCSExecutor
from repro.core.function import standard_pipeline
from repro.core.latency import LatencyModel
from repro.core.placement import StoragePool
from repro.core.platforms import PLATFORMS
from repro.core.scheduler import ClusterSim
from repro.core.workloads import WORKLOADS

LM = LatencyModel()


def _mean_speedup(plat, **kw):
    return float(np.mean([LM.e2e(PLATFORMS["Baseline-CPU"], wl, **kw)
                          / LM.e2e(PLATFORMS[plat], wl, **kw)
                          for wl in WORKLOADS.values()]))


# --------------------------------------------------------------------------
# paper claims (§VI) — reproduced within tolerance
# --------------------------------------------------------------------------

def test_claim_comm_dominates_baseline():
    comms = []
    for wl in WORKLOADS.values():
        bd = LM.pipeline_breakdown(PLATFORMS["Baseline-CPU"], wl)
        comms.append((bd["net"] + bd["io"]) / bd["total"])
    assert np.mean(comms) > 0.50          # paper: > 0.55 average


def test_claim_dscs_speedups():
    dsa = _mean_speedup("DSCS-Serverless")
    assert 2.8 <= dsa <= 4.5              # paper 3.6
    assert 2.0 <= dsa / _mean_speedup("GPU") <= 3.4       # paper 2.7
    assert 1.4 <= dsa / _mean_speedup("NS-FPGA") <= 2.3   # paper 1.7
    assert 2.9 <= dsa / _mean_speedup("NS-ARM") <= 5.5    # paper 3.7


def test_claim_ns_ordering():
    """NS-FPGA > NS-mobile-GPU > ~baseline >= NS-ARM (Fig. 8 ordering)."""
    assert _mean_speedup("NS-FPGA") > _mean_speedup("NS-Mobile-GPU") > 1.0
    assert _mean_speedup("NS-ARM") < 1.1


def test_claim_energy():
    dsa = float(np.mean([energy_reduction_vs_baseline(LM, wl, "DSCS-Serverless")
                         for wl in WORKLOADS.values()]))
    nsf = float(np.mean([energy_reduction_vs_baseline(LM, wl, "NS-FPGA")
                         for wl in WORKLOADS.values()]))
    assert dsa > 3.0                      # paper 3.5 (ours runs higher)
    assert 1.3 <= dsa / nsf <= 3.2        # paper 1.9


def test_claim_cost_efficiency():
    dsa = float(np.mean([cost_efficiency_vs_baseline(LM, wl, "DSCS-Serverless")
                         for wl in WORKLOADS.values()]))
    arm = float(np.mean([cost_efficiency_vs_baseline(LM, wl, "NS-ARM")
                         for wl in WORKLOADS.values()]))
    nsf = float(np.mean([cost_efficiency_vs_baseline(LM, wl, "NS-FPGA")
                         for wl in WORKLOADS.values()]))
    assert dsa > nsf > 1.0
    assert 2.2 <= dsa / arm <= 6.5        # paper 3.2
    assert 1.5 <= dsa / nsf <= 3.2        # paper 2.3


def test_claim_sensitivities_monotone():
    b = [_mean_speedup("DSCS-Serverless", batch=x) for x in (1, 16, 64)]
    assert b[0] < b[1] < b[2]             # Fig. 13
    f = [_mean_speedup("DSCS-Serverless", extra_accel_funcs=x)
         for x in (0, 2, 3)]
    assert f[0] < f[1] < f[2]             # Fig. 14
    assert (_mean_speedup("DSCS-Serverless", q=0.99)
            > _mean_speedup("DSCS-Serverless", q=0.5))     # Fig. 16
    assert (_mean_speedup("DSCS-Serverless", cold=True)
            < _mean_speedup("DSCS-Serverless"))            # Fig. 17


def test_claim_pcie_insensitive():
    vals = []
    for lanes in ("gen3x1", "gen3x16"):
        lm = LatencyModel()
        lm.pcie_lanes = lanes
        vals.append(float(np.mean(
            [lm.e2e(PLATFORMS["Baseline-CPU"], wl)
             / lm.e2e(PLATFORMS["DSCS-Serverless"], wl)
             for wl in WORKLOADS.values()])))
    assert abs(vals[1] / vals[0] - 1.0) < 0.05             # Fig. 15


# --------------------------------------------------------------------------
# DSE (Fig. 7)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dse_points():
    return sweep()


def test_dse_covers_650_configs(dse_points):
    assert len(dse_points) >= 400         # paper: >650 incl. repeats; ours 486


def test_dse_square_winner_matches_paper(dse_points):
    sq = optimal_square_design(dse_points)
    assert (sq.cfg.pe_x, sq.cfg.pe_y) == (128, 128)
    assert sq.cfg.mem_bw == 38e9          # DDR5
    paper_pt = evaluate(DSAConfig())
    assert paper_pt.throughput_fps >= 0.95 * sq.throughput_fps
    assert 3.0 <= dsa_power_w(DSAConfig()) <= 5.5          # paper 4.2 W


def test_dse_1024_infeasible(dse_points):
    big = evaluate(DSAConfig(pe_x=1024, pe_y=1024,
                             scratchpad_bytes=32 << 20, mem_bw=38e9))
    assert not big.feasible


def test_dse_pareto_nondominated(dse_points):
    front = pareto([p for p in dse_points if p.feasible], "power_w")
    for i, a in enumerate(front):
        for b in front:
            if b is a:
                continue
            assert not (b.power_w <= a.power_w
                        and b.throughput_fps > a.throughput_fps + 1e-9)


def test_tile_model_monotone_in_membw():
    g = GemmShape(512, 512, 512)
    slow = gemm_cycles(DSAConfig(mem_bw=19.2e9), g)[0]
    fast = gemm_cycles(DSAConfig(mem_bw=460e9), g)[0]
    assert fast <= slow


# --------------------------------------------------------------------------
# scheduler / placement / executor
# --------------------------------------------------------------------------

def test_scheduler_accelerates_and_falls_back():
    sim = ClusterSim(n_dscs=4, n_cpu=8, hedge_budget_s=0.05, seed=0)
    pipes = [standard_pipeline("asset_damage")]
    res = sim.run(pipes, rps=200, duration_s=10)     # overload 4 DSAs
    assert sim.telemetry.get("dscs_dispatch") > 0
    assert sim.telemetry.get("dscs_fallback") > 0    # busy -> CPU fallback
    accel = [r for r in res if r.accelerated]
    fallb = [r for r in res if not r.accelerated]
    assert accel and fallb


@pytest.mark.slow
def test_scheduler_throughput_dscs_beats_cpu():
    pipes = [standard_pipeline("content_moderation")]
    pipes_cpu = [standard_pipeline("content_moderation", accelerate=False)]
    dscs = ClusterSim(n_dscs=50, n_cpu=50, seed=1).max_throughput(
        pipes, sla_s=0.5, duration_s=10)
    cpu = ClusterSim(n_dscs=0, n_cpu=50, seed=1).max_throughput(
        pipes_cpu, sla_s=0.5, duration_s=10)
    assert dscs / cpu > 1.5               # paper 3.1 avg across suite


def test_placement_routes_acceleratable_to_dscs_drives():
    pool = StoragePool(n_plain=8, n_dscs=4)
    for i in range(64):
        d = pool.place(f"obj{i}", 1000, "Acceleratable_Storage")
        assert d.dscs_capable
    d = pool.locate("obj0")
    assert d is not None and d.has("obj0")


def test_placement_spreads_requests():
    pool = StoragePool(n_plain=0, n_dscs=8)
    drives = {pool.place(f"k{i}", 100, "Acceleratable_Storage").drive_id
              for i in range(200)}
    assert len(drives) == 8               # independent requests spread out


def test_placement_overwrite_accounting_exact():
    # the seed double-counted used_bytes on overwrite; it must stay exact
    pool = StoragePool(n_plain=0, n_dscs=2)
    d1 = pool.place("k", 1000, "Acceleratable_Storage")
    d2 = pool.place("k", 400, "Acceleratable_Storage")   # shrink in place
    assert d2 is d1
    assert d1.used_bytes == 400
    pool.place("k", 2500, "Acceleratable_Storage")       # grow in place
    assert d1.used_bytes == 2500
    assert sum(d.used_bytes for d in pool.drives) == 2500
    pool.remove("k")
    assert sum(d.used_bytes for d in pool.drives) == 0
    assert pool.locate("k") is None


def test_placement_payload_cap_enforced():
    # the seed asserted against a nonexistent "request" class — dead code;
    # the 256 KB cap must now be a live ValueError for request payloads
    from repro.core.placement import MAX_PAYLOAD_BYTES
    pool = StoragePool(n_plain=2, n_dscs=2)
    with pytest.raises(ValueError, match="cap"):
        pool.place("big", MAX_PAYLOAD_BYTES + 1, "Acceleratable_Storage")
    # at the cap is fine, and non-request classes are uncapped
    pool.place("ok", MAX_PAYLOAD_BYTES, "Acceleratable_Storage")
    pool.place("model", MAX_PAYLOAD_BYTES * 4, "Standard")


def test_placement_capacity_spills_to_least_full():
    import hashlib
    pool = StoragePool(n_plain=0, n_dscs=3, capacity_bytes=1000)
    # fill the drive "spill" hashes to, then place it: it must land on the
    # least-full drive that fits instead of overfilling
    h = int(hashlib.sha1(b"spill").hexdigest(), 16)
    target = pool.drives[h % 3]
    target.put("filler", 950)
    d = pool.place("spill", 200, "Acceleratable_Storage")
    assert d is not target
    assert d.used_bytes <= 1000
    # a pool with no room anywhere raises
    for dr in pool.drives:
        dr.put(f"pad-{dr.drive_id}", 1000 - dr.used_bytes)
    with pytest.raises(ValueError, match="no .* drive"):
        pool.place("nope", 1, "Acceleratable_Storage")
    # Drive.put itself refuses to overfill
    with pytest.raises(ValueError, match="over capacity"):
        pool.drives[0].put("extra", 1)


def test_placement_locate_index_matches_scan():
    pool = StoragePool(n_plain=2, n_dscs=4)
    for i in range(64):
        pool.place(f"k{i}", 10, "Acceleratable_Storage")
    for i in range(64):
        via_index = pool.locate(f"k{i}")
        via_scan = next(d for d in pool.drives if d.has(f"k{i}"))
        assert via_index is via_scan
    # keys put directly on a drive (bypassing place) still resolve
    pool.drives[0].put("direct", 5)
    assert pool.locate("direct") is pool.drives[0]


def test_placement_replica_sets_distinct_and_deterministic():
    pool = StoragePool(n_plain=2, n_dscs=6)
    for i in range(32):
        reps = pool.replicas(f"obj-{i}", 3)
        assert len(reps) == 3
        assert len({d.drive_id for d in reps}) == 3
        assert all(d.dscs_capable for d in reps)
        again = pool.replicas(f"obj-{i}", 3)
        assert [d.drive_id for d in reps] == [d.drive_id for d in again]
        # top-k is a prefix of top-(k+1): rendezvous hashing's stability
        wider = pool.replicas(f"obj-{i}", 4)
        assert [d.drive_id for d in wider[:3]] == [d.drive_id for d in reps]
    with pytest.raises(ValueError):
        pool.replicas("x", 0)


@pytest.mark.slow
def test_executor_runs_all_workloads():
    import jax
    key = jax.random.PRNGKey(0)
    for wl in WORKLOADS:
        ex = DSCSExecutor(wl, platform="DSCS-Serverless", image_size=32)
        rep = ex(ex.make_request(key))
        assert rep.latency_breakdown["total"] > 0
        assert rep.energy_breakdown["total"] > 0
        assert rep.accelerated
