"""Distribution: sharding rules, checkpoint/restart, fault tolerance,
EP-MoE equivalence on a multi-device (host-platform) mesh via subprocess."""
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import manager as ckpt
from repro.configs import ARCHS, get_arch, SHAPES_BY_NAME
from repro.distributed import sharding as SH
from repro.launch.mesh import make_local_mesh


class _FakeMesh:
    """Just enough of a Mesh for spec_for tests."""
    def __init__(self, shape):
        self.shape = shape
        self.size = int(np.prod(list(shape.values())))


def test_spec_divisibility_filtering():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # 40 heads * 96 = 3840 divides 16 -> shard; 40 alone does not
    sp = SH.spec_for((2560, 3840), ("fsdp", "tp"), SH.TRAIN_RULES, mesh)
    assert sp == P("data", "model")
    sp = SH.spec_for((40, 96), ("tp", None), SH.TRAIN_RULES, mesh)
    assert sp == P()                     # 40 % 16 != 0 -> replicated
    sp = SH.spec_for((256, 4096), ("batch", None), SH.TRAIN_RULES, mesh)
    assert sp == P("data") or sp == P(("pod", "data"))


def test_spec_no_axis_reuse():
    mesh = _FakeMesh({"data": 4, "model": 4})
    sp = SH.spec_for((64, 64, 64), ("tp", "tp", "fsdp"), SH.TRAIN_RULES, mesh)
    flat = [a for part in sp if part for a in
            (part if isinstance(part, tuple) else (part,))]
    assert len(flat) == len(set(flat))   # each mesh axis used at most once


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_build_for_all_archs(arch):
    """Spec trees must build (structure match) for every arch x both rule
    sets, on a production-shaped mesh."""
    from repro.models import transformer as T
    cfg = get_arch(arch)
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    shapes = T.param_shapes(cfg)
    axes = T.param_logical_axes(cfg)
    for rules in (SH.TRAIN_RULES, SH.TP_RULES):
        specs = SH.param_spec_tree(shapes, axes, rules, mesh)
        ns, nsh = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))), \
            len(jax.tree.leaves(shapes))
        assert ns == nsh


def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": [jnp.ones((2,)), jnp.zeros((5,), jnp.int32)]}
    for step in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), step, tree, extras={"step": step}, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored, step, extras = ckpt.restore(str(tmp_path), tree)
    assert step == 5 and extras["step"] == 5
    for g, w in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # retention: only 2 newest kept
    kept = [p for p in os.listdir(tmp_path) if p.startswith("step_")]
    assert len(kept) == 2


@pytest.mark.slow
def test_train_crash_restart_resumes_identically(tmp_path):
    """Fault tolerance: train 8 steps straight vs 4 + 'crash' + resume 4 —
    identical final loss (deterministic data stream + checkpointed state)."""
    from repro.launch.train import train
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    l_straight = train("qwen3-8b", smoke=True, steps=8, batch=2, seq=32,
                       ckpt_dir=d1, checkpoint_every=4, log_every=100)
    l_part1 = train("qwen3-8b", smoke=True, steps=8, batch=2, seq=32,
                    ckpt_dir=d2, checkpoint_every=4, log_every=100,
                    stop_at=4)   # simulated crash at step 4
    l_part2 = train("qwen3-8b", smoke=True, steps=8, batch=2, seq=32,
                    ckpt_dir=d2, checkpoint_every=4, resume=True,
                    log_every=100)
    assert abs(l_straight[-1] - l_part2[-1]) < 1e-4


@pytest.mark.slow
def test_grad_accumulation_matches_large_batch():
    from repro.launch.train import train
    import tempfile
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        l_big = train("mamba2-370m", smoke=True, steps=3, batch=4, seq=32,
                      ckpt_dir=d1, checkpoint_every=100, log_every=100)
        l_acc = train("mamba2-370m", smoke=True, steps=3, batch=4, seq=32,
                      microbatches=2, ckpt_dir=d2, checkpoint_every=100,
                      log_every=100)
    assert abs(l_big[0] - l_acc[0]) < 5e-2


_EP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.models.layers import moe_ffn
    from repro.distributed.moe_ep import moe_ffn_ep
    _at = getattr(jax.sharding, "AxisType", None)
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         **({"axis_types": (_at.Auto,) * 2} if _at else {}))
    key = jax.random.PRNGKey(0)
    B, S, D, E, F, K = 4, 8, 16, 8, 32, 2
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, D))
    wg = jax.random.normal(ks[1], (D, E))
    w1 = jax.random.normal(ks[2], (E, D, F)) * 0.1
    w3 = jax.random.normal(ks[3], (E, D, F)) * 0.1
    w2 = jax.random.normal(ks[4], (E, F, D)) * 0.1
    ref, _ = moe_ffn(x.reshape(B * S, D), wg, w1, w3, w2,
                     num_experts=E, k=K, capacity_factor=8.0)
    with mesh:
        got, _ = jax.jit(lambda *a: moe_ffn_ep(
            *a, num_experts=E, k=K, capacity_factor=8.0, act="silu",
            mesh=mesh, batch_axes=("data",)))(x, wg, w1, w3, w2)
    err = float(jnp.max(jnp.abs(got.reshape(B * S, D) - ref)))
    # NOTE: EP computes per-shard capacity; with a huge capacity factor both
    # paths route every token, so outputs must match.
    assert err < 1e-3, err
    print("EP_OK", err)
""")


@pytest.mark.slow
def test_moe_ep_matches_gather_path_on_8dev_mesh():
    """Expert-parallel shard_map MoE == single-device gather MoE (run in a
    subprocess so the 8-device host platform doesn't leak into this one)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _EP_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert "EP_OK" in r.stdout, r.stdout + r.stderr


def test_local_mesh_train_step_shards():
    mesh = make_local_mesh()
    assert mesh.size == len(jax.devices())
