"""Continuous batcher invariants + paper-suite configs smoke."""
import numpy as np
import pytest

from repro.serving.batcher import ContinuousBatcher, Request


def _toy_engine():
    """Deterministic fake engine: next token = last + 1."""
    def prefill_one(slot, prompt):
        return int(prompt[-1]) + 1

    def decode_batch(last, active):
        return (np.asarray(last)[:, 0] + 1) * np.asarray(active)

    return prefill_one, decode_batch


def test_batcher_completes_all_and_preserves_order():
    pre, dec = _toy_engine()
    b = ContinuousBatcher(4, pre, dec)
    reqs = [Request(rid=i, prompt=np.array([i * 10], np.int32), max_new=5)
            for i in range(10)]
    done = {}
    for r in reqs:
        b.submit(r)
    b.run_until_drained()
    assert b.stats["completed"] == 10
    for r in reqs:
        # token stream is prompt+1, +2, ... (engine semantics preserved
        # across slot reuse and interleaving)
        assert r.out == [r.prompt[-1] + 1 + j for j in range(5)]


def test_batcher_slot_utilization_reasonable():
    pre, dec = _toy_engine()
    b = ContinuousBatcher(4, pre, dec)
    for i in range(16):
        b.submit(Request(rid=i, prompt=np.array([0], np.int32), max_new=8))
    b.run_until_drained()
    assert b.slot_utilization > 0.9      # continuous batching keeps slots hot


def test_batcher_mixed_lengths_free_slots_early():
    pre, dec = _toy_engine()
    b = ContinuousBatcher(2, pre, dec)
    b.submit(Request(rid=0, prompt=np.array([0], np.int32), max_new=2))
    b.submit(Request(rid=1, prompt=np.array([0], np.int32), max_new=20))
    b.submit(Request(rid=2, prompt=np.array([0], np.int32), max_new=2))
    b.run_until_drained()
    assert b.stats["completed"] == 3
    # the short third request slotted in long before request 1 finished
    assert b.steps < 25


def test_batcher_fifo_admission_order():
    """Free slots must be granted in submission (FIFO) order."""
    pre, dec = _toy_engine()
    admitted = []

    def tracking_prefill(slot, prompt):
        admitted.append(int(prompt[-1]))
        return pre(slot, prompt)

    b = ContinuousBatcher(2, tracking_prefill, dec)
    for i in range(8):
        b.submit(Request(rid=i, prompt=np.array([i], np.int32), max_new=3))
    b.run_until_drained()
    assert admitted == sorted(admitted) == list(range(8))


def test_batcher_slot_reuse_after_completion():
    """With 1 slot and N requests, the slot must be reused N times and
    hold at most one live request at a time."""
    pre, dec = _toy_engine()
    b = ContinuousBatcher(1, pre, dec)
    for i in range(5):
        b.submit(Request(rid=i, prompt=np.array([i], np.int32), max_new=2))
    while b.queue or b.live:
        assert len(b.live) <= 1
        b.step()
    assert b.stats["completed"] == 5
    assert b.stats["admitted"] == 5


def test_batcher_slot_utilization_bounds():
    pre, dec = _toy_engine()
    b = ContinuousBatcher(4, pre, dec)
    assert b.slot_utilization == 0.0          # no decode steps yet
    for i in range(3):                        # fewer requests than slots
        b.submit(Request(rid=i, prompt=np.array([0], np.int32), max_new=4))
    b.run_until_drained()
    assert 0.0 <= b.slot_utilization <= 1.0
    assert b.slot_utilization <= 3.0 / 4.0 + 1e-9   # 1 slot always idle


def test_batcher_drain_terminates_under_max_steps():
    """run_until_drained must stop at max_steps even with work left."""
    pre, dec = _toy_engine()
    b = ContinuousBatcher(1, pre, dec)
    b.submit(Request(rid=0, prompt=np.array([0], np.int32), max_new=10_000))
    b.run_until_drained(max_steps=7)
    assert b.steps == 7
    assert b.stats["completed"] == 0 and b.live   # still in flight, no hang


def test_paper_suite_configs_build():
    import jax
    from repro.configs.paper_suite import PAPER_LM_SUITE
    from repro.models import transformer as T
    for name, cfg in PAPER_LM_SUITE.items():
        r = cfg.reduced()
        params = T.init_params(r, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                                    r.vocab_size)
        kw = {}
        if r.frontend == "vision_patches":
            import jax.numpy as jnp
            kw["frontend_embeds"] = jnp.zeros((1, r.frontend_seq, r.d_model),
                                              r.dtype)
        logits = T.forward(r, params, tokens, **kw)
        assert logits.shape[-1] in (r.vocab_size, r.padded_vocab)
