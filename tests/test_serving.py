"""Continuous batcher invariants + paper-suite configs smoke."""
import numpy as np
import pytest

from repro.serving.batcher import ContinuousBatcher, Request


def _toy_engine():
    """Deterministic fake engine: next token = last + 1."""
    def prefill_one(slot, prompt):
        return int(prompt[-1]) + 1

    def decode_batch(last, active):
        return (np.asarray(last)[:, 0] + 1) * np.asarray(active)

    return prefill_one, decode_batch


def test_batcher_completes_all_and_preserves_order():
    pre, dec = _toy_engine()
    b = ContinuousBatcher(4, pre, dec)
    reqs = [Request(rid=i, prompt=np.array([i * 10], np.int32), max_new=5)
            for i in range(10)]
    done = {}
    for r in reqs:
        b.submit(r)
    b.run_until_drained()
    assert b.stats["completed"] == 10
    for r in reqs:
        # token stream is prompt+1, +2, ... (engine semantics preserved
        # across slot reuse and interleaving)
        assert r.out == [r.prompt[-1] + 1 + j for j in range(5)]


def test_batcher_slot_utilization_reasonable():
    pre, dec = _toy_engine()
    b = ContinuousBatcher(4, pre, dec)
    for i in range(16):
        b.submit(Request(rid=i, prompt=np.array([0], np.int32), max_new=8))
    b.run_until_drained()
    assert b.slot_utilization > 0.9      # continuous batching keeps slots hot


def test_batcher_mixed_lengths_free_slots_early():
    pre, dec = _toy_engine()
    b = ContinuousBatcher(2, pre, dec)
    b.submit(Request(rid=0, prompt=np.array([0], np.int32), max_new=2))
    b.submit(Request(rid=1, prompt=np.array([0], np.int32), max_new=20))
    b.submit(Request(rid=2, prompt=np.array([0], np.int32), max_new=2))
    b.run_until_drained()
    assert b.stats["completed"] == 3
    # the short third request slotted in long before request 1 finished
    assert b.steps < 25


def test_paper_suite_configs_build():
    import jax
    from repro.configs.paper_suite import PAPER_LM_SUITE
    from repro.models import transformer as T
    for name, cfg in PAPER_LM_SUITE.items():
        r = cfg.reduced()
        params = T.init_params(r, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                                    r.vocab_size)
        kw = {}
        if r.frontend == "vision_patches":
            import jax.numpy as jnp
            kw["frontend_embeds"] = jnp.zeros((1, r.frontend_seq, r.d_model),
                                              r.dtype)
        logits = T.forward(r, params, tokens, **kw)
        assert logits.shape[-1] in (r.vocab_size, r.padded_vocab)
