"""Discrete-event cluster engine: invariants, determinism, hedging,
data-aware placement, the arrival-process library, and golden-trace
equivalence of the array-backed hot path against the frozen pre-PR2
reference engine."""
import json
import math
import pathlib

import numpy as np
import pytest

from repro.core.arrivals import (BurstyOnOff, DiurnalProcess, PoissonProcess,
                                 TraceReplay, make_arrivals)
from repro.core.function import standard_pipeline
from repro.core.placement import StoragePool
from repro.core.scheduler import ClusterSim

GOLDEN = pathlib.Path(__file__).parent / "golden"
PIPES = [standard_pipeline(n) for n in ("asset_damage", "content_moderation")]


def _overloaded_sim(seed=0, hedge=0.05):
    return ClusterSim(n_dscs=4, n_cpu=8, hedge_budget_s=hedge, seed=seed)


# --------------------------------------------------------------------------
# engine invariants
# --------------------------------------------------------------------------

def test_every_arrival_produces_exactly_one_result():
    sim = _overloaded_sim()
    arr = PoissonProcess(rate=80.0)
    n_arrivals = len(arr.times(10.0, np.random.default_rng(
        np.random.SeedSequence(0).spawn(2)[0])))
    res = sim.run(PIPES, arrivals=arr, duration_s=10)
    assert len(res) == n_arrivals
    assert all(r is not None for r in res)


def test_time_ordering_invariants():
    res = _overloaded_sim().run(PIPES, rps=100, duration_s=10)
    for r in res:
        assert r.start >= r.arrival - 1e-9
        assert r.service > 0.0
        assert r.finish >= r.arrival + r.service - 1e-9
        assert abs(r.finish - (r.start + r.service)) < 1e-9


def test_fcfs_order_per_drive():
    """DSCS-served requests on one drive must start in arrival order."""
    res = _overloaded_sim().run(PIPES, rps=100, duration_s=10)
    by_drive = {}
    for r in res:
        if r.winner == "dscs":
            by_drive.setdefault(r.drive, []).append(r)
    assert by_drive
    for drive, rs in by_drive.items():
        rs.sort(key=lambda r: r.arrival)
        starts = [r.start for r in rs]
        assert starts == sorted(starts), f"drive {drive} broke FCFS"


def test_hedged_winner_latency_le_both_paths():
    res = _overloaded_sim().run(PIPES, rps=150, duration_s=10)
    hedged = [r for r in res if r.hedged]
    assert hedged, "overloaded scenario must hedge"
    both = [r for r in hedged
            if r.dscs_finish is not None and r.cpu_finish is not None]
    assert both, "some hedges must race to completion on both paths"
    for r in both:
        assert r.finish <= min(r.dscs_finish, r.cpu_finish) + 1e-9
    # winner attribution is coherent
    for r in hedged:
        assert r.winner in ("dscs", "cpu")
        assert r.accelerated == (r.winner == "dscs")


def test_hedging_observable_and_telemetry_consistent():
    sim = _overloaded_sim()
    res = sim.run(PIPES, rps=150, duration_s=10)
    tel = sim.telemetry
    assert tel.get("dscs_dispatch") > 0
    assert tel.get("hedge_issued") > 0
    assert tel.get("hedge_issued") == tel.get("dscs_fallback")
    assert (tel.get("hedge_won_dscs") + tel.get("hedge_won_cpu")
            == sum(r.hedged for r in res))
    q = sim.queue_stats()
    assert q["dscs"]["max_depth"] >= q["dscs"]["mean_depth"] >= 0.0


def test_no_dscs_fleet_serves_everything_on_cpu():
    res = ClusterSim(n_dscs=0, n_cpu=8, seed=0).run(PIPES, rps=30,
                                                    duration_s=5)
    assert res and all(not r.accelerated and r.winner == "cpu" for r in res)


def test_data_aware_placement_matches_storage_pool_hash():
    """The engine must dispatch to the drive the placement hash selects,
    not a random draw."""
    sim = ClusterSim(n_dscs=8, n_cpu=8, seed=0)
    res = sim.run([standard_pipeline("asset_damage")], rps=40, duration_s=5)
    pool = StoragePool(n_plain=64, n_dscs=8)
    idx = {d.drive_id: i for i, d in enumerate(pool.dscs_drives())}
    for rid, r in enumerate(res):
        if r.winner != "dscs":
            continue
        want = idx[pool.place(f"req-{rid}", 1, "Acceleratable_Storage")
                   .drive_id]
        assert r.drive == want


# --------------------------------------------------------------------------
# seeded reproducibility
# --------------------------------------------------------------------------

def test_golden_trace_identical_across_runs():
    """Two sims with one seed emit identical RequestResult streams; the
    same sim re-run also replays exactly."""
    a_sim = _overloaded_sim(seed=13)
    a = a_sim.run(PIPES, rps=60, duration_s=8)
    b = _overloaded_sim(seed=13).run(PIPES, rps=60, duration_s=8)
    assert len(a) == len(b) > 0
    assert a == b
    assert a_sim.run(PIPES, rps=60, duration_s=8) == a


def test_different_seeds_differ():
    a = _overloaded_sim(seed=0).run(PIPES, rps=60, duration_s=8)
    b = _overloaded_sim(seed=1).run(PIPES, rps=60, duration_s=8)
    assert a != b


def test_bursty_golden_trace():
    arr = BurstyOnOff(rate=50.0)
    a = _overloaded_sim(seed=3).run(PIPES, arrivals=arr, duration_s=8)
    b = _overloaded_sim(seed=3).run(PIPES, arrivals=arr, duration_s=8)
    assert a == b and len(a) > 0


# --------------------------------------------------------------------------
# golden-trace gates: the optimized engine must reproduce the pre-refactor
# RequestResult stream bit-for-bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [13, 21])
def test_golden_trace_pins_pre_refactor_stream(seed):
    """The exact pre-PR2 RequestResult stream, captured from the frozen
    reference engine and committed as JSON, must be reproduced field-for-
    field (float equality, no tolerance) by the optimized engine."""
    golden = json.loads((GOLDEN / f"engine_trace_seed{seed}.json").read_text())
    cfg = golden["config"]
    sim = ClusterSim(n_dscs=cfg["n_dscs"], n_cpu=cfg["n_cpu"],
                     hedge_budget_s=cfg["hedge_budget_s"], seed=cfg["seed"])
    res = sim.run([standard_pipeline(n) for n in cfg["pipelines"]],
                  arrivals=PoissonProcess(rate=cfg["rate"]),
                  duration_s=cfg["duration_s"])
    assert len(res) == golden["n"]
    for i, (r, row) in enumerate(zip(res, golden["results"])):
        got = [r.arrival, r.finish, r.accelerated, r.hedged, r.winner,
               r.drive, r.start, r.service, r.dscs_finish, r.cpu_finish]
        assert got == row, f"request {i} deviates from the pinned trace"


@pytest.mark.parametrize("seed", [13, 21])
def test_optimized_engine_matches_frozen_reference(seed):
    """Live old-vs-new gate: the frozen object-based reference engine and
    the array-backed engine must emit identical RequestResult streams and
    identical telemetry for the same seed (portable across hosts because
    both consume the same vectorized sampler stream)."""
    from repro.core.engine import ClusterEngine
    from repro.core.engine_ref import ReferenceClusterEngine

    kw = dict(n_dscs=4, n_cpu=8, hedge_budget_s=0.05, seed=seed)
    arr = BurstyOnOff(rate=70.0, burst_factor=4.0)
    ref = ReferenceClusterEngine(**kw)
    new = ClusterEngine(**kw)
    a = ref.run(PIPES, arrivals=arr, duration_s=8)
    b = new.run(PIPES, arrivals=arr, duration_s=8)
    assert len(a) == len(b) > 0
    assert a == b
    for k in ("dscs_dispatch", "cpu_dispatch", "hedge_issued",
              "dscs_fallback", "hedge_won_dscs", "hedge_won_cpu",
              "dscs_served", "cpu_served", "cancelled_in_queue",
              "cancelled_in_service"):
        assert ref.telemetry.get(k) == new.telemetry.get(k), k


def test_run_soa_consistent_with_object_stream():
    """The SoA trace and the materialized RequestResult stream are two
    views of the same run."""
    sim = _overloaded_sim(seed=2)
    trace = sim.engine.run_soa(PIPES, arrivals=PoissonProcess(rate=80.0),
                               duration_s=6)
    res = trace.to_results()
    assert trace.n == len(res) > 0
    assert trace.events > 2 * trace.n           # arrivals + finishes at least
    lat = trace.latency
    for i, r in enumerate(res):
        assert r.latency == lat[i]
        assert (r.winner == "dscs") == (trace.winner[i] == 0)
        assert r.drive == trace.drive[i]
    # a fresh run through the object API replays exactly
    assert sim.run(PIPES, rps=80.0, duration_s=6) == res


def test_sample_bank_replays_identically():
    """Banked runs (common random numbers) are exactly reproducible."""
    sim = _overloaded_sim(seed=11)
    eng = sim.engine
    bank = eng.sample_bank(PIPES)
    times = PoissonProcess(rate=90.0).times(6.0, np.random.default_rng(0))
    a = eng.run_soa(PIPES, times=times, bank=bank)
    b = eng.run_soa(PIPES, times=times, bank=bank)
    assert np.array_equal(a.finish, b.finish)
    assert np.array_equal(a.winner, b.winner)
    assert np.array_equal(a.service, b.service)


# --------------------------------------------------------------------------
# deque + tombstone cancellation (satellite: tombstones are never started)
# --------------------------------------------------------------------------

def test_tombstoned_copies_are_never_started():
    """Queue-cancelled losers must never receive service: every such loser
    leaves exactly one path finish time unset, the dispatch loop discards
    (never starts) surfaced tombstones, and the engine asserts on any
    non-queued copy reaching the server."""
    sim = ClusterSim(n_dscs=3, n_cpu=6, hedge_budget_s=0.02, seed=4)
    res = sim.run(PIPES, rps=120.0, duration_s=12)
    tel = sim.telemetry
    assert tel.get("cancelled_in_queue") > 0, "scenario must cancel in queue"
    # a cancelled-in-queue loser never ran: exactly one path finish is None
    one_sided = sum(1 for r in res if r.hedged
                    and (r.dscs_finish is None) != (r.cpu_finish is None))
    assert one_sided == tel.get("cancelled_in_queue")
    # the winner's path always finished
    for r in res:
        assert (r.dscs_finish if r.winner == "dscs" else r.cpu_finish) is not None
    # tombstones actually surfaced and were discarded by the dispatch loop,
    # and no more of them than copies cancelled while queued
    assert 0 < tel.get("tombstones_discarded") <= tel.get("cancelled_in_queue")


# --------------------------------------------------------------------------
# preemptive loser cancellation (engine flag; reclaimed-seconds telemetry)
# --------------------------------------------------------------------------

def test_preemptive_loser_cancellation_reclaims_server_seconds():
    """With ``preempt_losers=True`` an in-service hedge loser is cancelled
    immediately: its server is freed, its path finish time stays unset
    (it never completed), and the remaining service is counted as
    reclaimed seconds — strictly positive in a hedging-heavy scenario."""
    from repro.core.engine import ClusterEngine
    kw = dict(n_dscs=3, n_cpu=6, hedge_budget_s=0.02, seed=4)
    arr = PoissonProcess(rate=120.0)

    base = ClusterEngine(**kw)
    base.run(PIPES, arrivals=arr, duration_s=12)
    assert base.telemetry.get("cancelled_in_service") > 0
    assert base.telemetry.get("reclaimed_dscs_s") == 0.0
    assert base.telemetry.get("reclaimed_cpu_s") == 0.0

    eng = ClusterEngine(preempt_losers=True, **kw)
    res = eng.run(PIPES, arrivals=arr, duration_s=12)
    tel = eng.telemetry
    assert tel.get("cancelled_in_service") > 0
    reclaimed = tel.get("reclaimed_dscs_s") + tel.get("reclaimed_cpu_s")
    assert reclaimed > 0.0
    # every request still completes, and every cancelled loser (queued OR
    # in-service) now leaves exactly one path finish unset
    assert all(r.finish >= r.arrival for r in res)
    one_sided = sum(1 for r in res if r.hedged
                    and (r.dscs_finish is None) != (r.cpu_finish is None))
    assert one_sided == (tel.get("cancelled_in_queue")
                         + tel.get("cancelled_in_service"))
    # reclaimed time shrinks the busy-seconds integral versus the
    # run-to-completion baseline (the drives/CPUs did strictly less work)
    ps_base, ps_pre = base.power_stats(), eng.power_stats()
    assert (ps_pre["dscs"]["busy_s"] + ps_pre["cpu"]["busy_s"]
            < ps_base["dscs"]["busy_s"] + ps_base["cpu"]["busy_s"])


def test_preemption_reclaims_nothing_without_hedging():
    """No hedging -> no losers -> nothing to reclaim, flag or not; the
    stream must equal the unflagged engine's bit-for-bit."""
    from repro.core.engine import ClusterEngine
    kw = dict(n_dscs=3, n_cpu=6, hedge_budget_s=None, seed=4)
    arr = PoissonProcess(rate=120.0)
    a = ClusterEngine(preempt_losers=True, **kw).run(PIPES, arrivals=arr,
                                                     duration_s=8)
    eng = ClusterEngine(**kw)
    b = eng.run(PIPES, arrivals=arr, duration_s=8)
    assert a == b
    flagged = ClusterEngine(preempt_losers=True, **kw)
    flagged.run(PIPES, arrivals=arr, duration_s=8)
    assert flagged.telemetry.get("reclaimed_dscs_s") == 0.0
    assert flagged.telemetry.get("reclaimed_cpu_s") == 0.0


# --------------------------------------------------------------------------
# DiurnalProcess / TraceReplay interop (satellite: round-trip fidelity)
# --------------------------------------------------------------------------

def test_trace_replay_round_trips_generated_stream_bit_exactly():
    """Recording a generated arrival stream and replaying it through
    TraceReplay must reproduce the original engine run exactly — any
    float re-quantization in the tuple round-trip would shift every
    queueing decision downstream."""
    arr = DiurnalProcess(rate=300.0, amplitude=0.8, period_s=10.0)
    # the exact stream the engine draws internally for this seed: child 0
    # of the engine SeedSequence feeds the arrival process
    ts = arr.times(12.0, np.random.default_rng(
        np.random.SeedSequence(7).spawn(2)[0]))
    sim_live = ClusterSim(n_dscs=4, n_cpu=8, hedge_budget_s=0.05, seed=7)
    a = sim_live.run(PIPES, arrivals=arr, duration_s=12)

    replay = TraceReplay(trace=ts)              # numpy array input
    assert isinstance(replay.trace, tuple)      # normalized, hashable
    assert all(isinstance(t, float) for t in replay.trace)
    sim_replay = ClusterSim(n_dscs=4, n_cpu=8, hedge_budget_s=0.05, seed=7)
    b = sim_replay.run(PIPES, arrivals=replay, duration_s=12)
    assert len(a) == len(b) > 0
    assert a == b
    # and the replay's own output is the recorded stream, bit-for-bit
    assert np.array_equal(
        replay.times(12.0, np.random.default_rng(0)), ts)


# --------------------------------------------------------------------------
# queue_stats: common end-of-run horizon (satellite fix)
# --------------------------------------------------------------------------

def test_queue_stats_uses_common_end_of_run_horizon():
    """Four simultaneous arrivals on two CPU nodes: each node's depth
    integral is its first service time, and the mean is taken over the
    horizon of the *last* completion fleet-wide — not each server's own
    last-activity time, which deflated the denominator before the fix."""
    sim = ClusterSim(n_dscs=0, n_cpu=2, seed=0)
    res = sim.run([standard_pipeline("asset_damage")],
                  arrivals=TraceReplay(rate=0.0, trace=(0.0, 0.0, 0.0, 0.0)),
                  duration_s=10.0)
    assert len(res) == 4
    r = sorted(res, key=lambda x: x.arrival)    # all at t=0, arrival order kept
    # rid0 -> node0, rid1 -> node1, rid2 queues on node0, rid3 on node1
    f0, f1 = r[0].finish, r[1].finish
    horizon = max(r[2].finish, r[3].finish)
    q = sim.queue_stats()["cpu"]
    assert q["max_depth"] == 1.0
    want = (f0 + f1) / (2.0 * horizon)
    assert abs(q["mean_depth"] - want) < 1e-12
    # the pre-fix per-server-horizon formula would have inflated the mean
    assert q["mean_depth"] < (f0 + f1) / (2.0 * max(f0, f1))


# --------------------------------------------------------------------------
# straggler mitigation (Fig. 16 claim, acceptance criterion)
# --------------------------------------------------------------------------

def test_hedging_lowers_p99_under_bursty_load():
    pipes = [standard_pipeline("content_moderation")]
    arr = BurstyOnOff(rate=120.0, burst_factor=5.0, mean_on_s=1.0,
                      mean_off_s=4.0)
    off = ClusterSim(n_dscs=6, n_cpu=24, hedge_budget_s=None, seed=0).run(
        pipes, arrivals=arr, duration_s=30)
    on = ClusterSim(n_dscs=6, n_cpu=24, hedge_budget_s=0.1, seed=0).run(
        pipes, arrivals=arr, duration_s=30)
    assert sum(r.hedged for r in on) > 0
    p99_off = float(np.percentile([r.latency for r in off], 99))
    p99_on = float(np.percentile([r.latency for r in on], 99))
    assert p99_on < p99_off


# --------------------------------------------------------------------------
# service-time cache
# --------------------------------------------------------------------------

def test_service_cache_survives_equal_sigmas():
    """read_sigma == write_sigma makes the tail columns collinear; the
    decomposition must fall back gracefully, not crash."""
    from repro.core.latency import LatencyModel, LatencyParams
    lm = LatencyModel(params=LatencyParams(read_sigma=0.4, write_sigma=0.4))
    res = ClusterSim(n_dscs=2, n_cpu=4, latency_model=lm, seed=0).run(
        PIPES, rps=20, duration_s=3)
    assert res and all(r.service > 0 for r in res)


def test_service_cache_keyed_by_workload_not_object_identity():
    """Freshly-constructed Pipeline objects (recycled ids) must hit the
    right cached coefficients: same workload -> same draw sequence."""
    sim = ClusterSim(n_dscs=2, n_cpu=4, seed=5)
    a = sim.run([standard_pipeline("asset_damage")], rps=30, duration_s=3)
    for _ in range(50):                  # churn allocator to recycle ids
        sim.run([standard_pipeline("content_moderation")], rps=30,
                duration_s=1)
    b = sim.run([standard_pipeline("asset_damage")], rps=30, duration_s=3)
    assert a == b


# --------------------------------------------------------------------------
# arrival processes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("proc,horizon", [
    (PoissonProcess(200.0), 60.0),
    # one ON/OFF cycle averages 10 s, so the MMPP needs a much longer
    # window before its sample mean settles near the nominal rate
    (BurstyOnOff(200.0), 600.0),
    (DiurnalProcess(200.0), 60.0),
])
def test_arrivals_sorted_deterministic_and_rate_calibrated(proc, horizon):
    rng = np.random.default_rng(0)
    ts = proc.times(horizon, rng)
    assert np.all(np.diff(ts) >= 0.0)
    assert np.all((ts >= 0.0) & (ts < horizon))
    # same seed replays, different seed does not
    assert np.array_equal(ts, proc.times(horizon, np.random.default_rng(0)))
    assert not np.array_equal(ts, proc.times(horizon,
                                             np.random.default_rng(1)))
    # long-run mean rate within 20% of nominal
    assert 0.8 * 200 * horizon < ts.size < 1.2 * 200 * horizon


def test_diurnal_period_wraparound():
    """The sinusoidal profile must wrap seamlessly across period
    boundaries: per-period counts stay near the mean, and every period's
    peak half out-draws its trough half."""
    proc = DiurnalProcess(rate=300.0, amplitude=0.8, period_s=10.0)
    ts = proc.times(50.0, np.random.default_rng(7))    # five full periods
    per_period = np.histogram(ts, bins=np.arange(0.0, 51.0, 10.0))[0]
    assert per_period.size == 5
    # each period offers ~rate*period on average regardless of phase
    assert np.all(per_period > 0.7 * 3000) and np.all(per_period < 1.3 * 3000)
    for k in range(5):
        base = 10.0 * k
        peak = np.count_nonzero((ts >= base) & (ts < base + 5.0))
        trough = np.count_nonzero((ts >= base + 5.0) & (ts < base + 10.0))
        assert peak > trough, f"period {k}: peak half must out-draw trough"


def test_diurnal_rate_floor_at_trough():
    """Amplitude > 1 clips the instantaneous rate at zero: the dead-of-
    night window where 1 + amp*sin(2πt/P) <= 0 must hold no arrivals at
    all, while the stream stays sorted, in-window and rate-positive."""
    proc = DiurnalProcess(rate=400.0, amplitude=1.5, period_s=10.0)
    ts = proc.times(30.0, np.random.default_rng(0))
    assert ts.size > 0
    assert np.all(np.diff(ts) >= 0.0)
    assert np.all((ts >= 0.0) & (ts < 30.0))
    phase = np.sin(2.0 * math.pi * ts / 10.0)
    assert np.all(1.0 + 1.5 * phase > 0.0), \
        "arrivals appeared inside the clipped zero-rate window"
    # clipping removes the negative lobe, so the realized mean rate must
    # match the *floored* profile's mean (above the nominal parameter),
    # not the unclipped sinusoid's
    theta = np.linspace(0.0, 2.0 * math.pi, 20000, endpoint=False)
    clipped_mean = 400.0 * float(
        np.mean(np.maximum(0.0, 1.0 + 1.5 * np.sin(theta))))
    assert clipped_mean > 400.0
    assert 0.85 * clipped_mean * 30 < ts.size < 1.15 * clipped_mean * 30


def test_diurnal_parameter_validation():
    with pytest.raises(ValueError):
        DiurnalProcess(rate=10.0, amplitude=-0.1)
    with pytest.raises(ValueError):
        DiurnalProcess(rate=10.0, period_s=0.0)


def test_trace_replay_exact_and_unscalable():
    trace = (0.5, 0.1, 3.0, 99.0)
    proc = TraceReplay(rate=0.0, trace=trace)
    ts = proc.times(10.0, np.random.default_rng(0))
    assert ts.tolist() == [0.1, 0.5, 3.0]
    with pytest.raises(TypeError):
        proc.with_rate(5.0)


def test_with_rate_returns_rescaled_copy():
    p = BurstyOnOff(100.0, burst_factor=3.0)
    q = p.with_rate(10.0)
    assert q.rate == 10.0 and q.burst_factor == 3.0
    assert p.rate == 100.0


def test_make_arrivals_factory():
    assert isinstance(make_arrivals("poisson", 5.0), PoissonProcess)
    assert isinstance(make_arrivals("bursty", 5.0), BurstyOnOff)
    with pytest.raises(ValueError):
        make_arrivals("fractal", 5.0)


def test_ambiguous_load_spec_rejected():
    with pytest.raises(ValueError):
        ClusterSim(n_dscs=2, n_cpu=2).run(PIPES, rps=200,
                                          arrivals=PoissonProcess(5.0),
                                          duration_s=1)


def test_bursty_degenerate_phases_rejected():
    with pytest.raises(ValueError):
        BurstyOnOff(100.0, mean_off_s=0.0).times(1.0,
                                                 np.random.default_rng(0))
