"""Hypothesis property-based tests on system invariants.

The whole module is skipped (not an error) when hypothesis is absent —
``requirements-dev.txt`` installs it for the full suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.dsa import DSAConfig, GemmShape, gemm_cycles, network_flops
from repro.core.latency import LatencyModel
from repro.core.placement import StoragePool
from repro.kernels import ref
from repro.models import layers as L
from repro.models.transformer import softmax_xent

LM = LatencyModel()


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 2048), st.integers(1, 2048))
def test_tile_model_cycles_bound_by_physics(m, k, n):
    """Total cycles >= both the pure-compute and pure-DMA lower bounds."""
    cfg = DSAConfig()
    g = GemmShape(m, k, n)
    total, comp, dma = gemm_cycles(cfg, g)
    assert total + 1e-6 >= comp
    assert total + 1e-6 >= dma
    # throughput can never exceed the array peak
    flops = 2.0 * m * k * n
    assert flops / (total / cfg.freq_hz) <= 2.05 * cfg.pe_x * cfg.pe_y * cfg.freq_hz


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1 << 24), st.integers(0, 1 << 24))
def test_latency_monotone_in_size(a, b):
    lo, hi = sorted((a, b))
    assert LM.net_read(lo) <= LM.net_read(hi) + 1e-12
    assert LM.net_write(lo) <= LM.net_write(hi) + 1e-12
    assert LM.p2p(lo) <= LM.p2p(hi) + 1e-12


@settings(max_examples=20, deadline=None)
@given(st.floats(0.01, 0.99), st.floats(0.01, 0.99))
def test_latency_tail_quantiles_monotone(q1, q2):
    lo, hi = sorted((q1, q2))
    assert LM.net_read(10_000, q=lo) <= LM.net_read(10_000, q=hi) + 1e-12


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(2, 16), st.integers(1, 4))
def test_moe_capacity_and_conservation(t, e, k):
    """Every kept slot holds a valid token; combine weights are a sub-convex
    mixture (dropped tokens only ever lose mass)."""
    k = min(k, e)
    key = jax.random.PRNGKey(t * 131 + e * 7 + k)
    x = jax.random.normal(key, (t, 8))
    wg = jax.random.normal(key, (8, e))
    w1 = jax.random.normal(key, (e, 8, 16)) * 0.1
    w3 = jax.random.normal(key, (e, 8, 16)) * 0.1
    w2 = jax.random.normal(key, (e, 16, 8)) * 0.1
    out, aux = L.moe_ffn(x, wg, w1, w3, w2, num_experts=e, k=k,
                         capacity_factor=1.25)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) >= 0.4   # Switch aux ~1 at balance; small-T noise


@settings(max_examples=5, deadline=None)
@given(st.sampled_from([(1, 2), (2, 4), (4, 8), (2, 8), (1, 16)]))
def test_max_throughput_monotone_in_n_dscs(pair):
    """With common random numbers (one SampleBank + one cached arrival
    stream per search), adding DSCS drives never lowers the SLA-feasible
    throughput: every probe sees the same picks/service draws, so fleets
    differ only through capacity."""
    from repro.core.function import standard_pipeline
    from repro.core.scheduler import ClusterSim

    lo_d, hi_d = pair
    pipes = [standard_pipeline("content_moderation")]
    kw = dict(sla_s=0.6, duration_s=4.0, hi=512.0)
    lo = ClusterSim(n_dscs=lo_d, n_cpu=12, seed=9).max_throughput(pipes, **kw)
    hi = ClusterSim(n_dscs=hi_d, n_cpu=12, seed=9).max_throughput(pipes, **kw)
    assert hi >= lo - 1e-9


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(0, 5000)),
                min_size=1, max_size=60),
       st.integers(2, 6))
def test_storage_accounting_exact_under_put_overwrite(ops, n_dscs):
    """sum(drive.used_bytes) always equals the live object total, under
    arbitrary put/overwrite sequences (the seed double-counted every
    overwrite, drifting used_bytes away from reality)."""
    pool = StoragePool(n_plain=2, n_dscs=n_dscs)
    live = {}
    for key_id, size in ops:
        key = f"k{key_id}"
        pool.place(key, size, "Acceleratable_Storage")
        live[key] = size
    assert sum(d.used_bytes for d in pool.drives) == sum(live.values())
    # per-drive accounting agrees with each drive's own object map
    for d in pool.drives:
        assert d.used_bytes == sum(d.objects.values())
    # every live key is exactly on one drive, findable via the index
    for key, size in live.items():
        holders = [d for d in pool.drives if d.has(key)]
        assert len(holders) == 1
        assert pool.locate(key) is holders[0]


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(2, 50))
def test_placement_deterministic_and_class_respecting(n_dscs, n_obj):
    p1 = StoragePool(n_plain=3, n_dscs=n_dscs)
    p2 = StoragePool(n_plain=3, n_dscs=n_dscs)
    for i in range(n_obj):
        d1 = p1.place(f"o{i}", 10, "Acceleratable_Storage")
        d2 = p2.place(f"o{i}", 10, "Acceleratable_Storage")
        assert d1.drive_id == d2.drive_id      # deterministic
        assert d1.dscs_capable                  # class respected


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(2, 16), st.integers(2, 50))
def test_softmax_xent_matches_naive(b, s, v):
    key = jax.random.PRNGKey(b * 100 + s * 10 + v)
    logits = jax.random.normal(key, (b, s, v))
    labels = jax.random.randint(key, (b, s), 0, v)
    got = softmax_xent(logits, labels)
    lp = jax.nn.log_softmax(logits, axis=-1)
    want = -jnp.mean(jnp.take_along_axis(lp, labels[..., None], -1))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(8, 64), st.integers(8, 64))
def test_quantize_error_bounded(b, m, n):
    key = jax.random.PRNGKey(b * 7 + m * 3 + n)
    x = jax.random.normal(key, (m, n)) * (b * 2.0)
    q, s = ref.quantize_int8_ref(x)
    xd = ref.dequantize_int8_ref(q, s)
    assert float(jnp.max(jnp.abs(xd - x))) <= float(jnp.max(s)) * 0.5 + 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.integers(4, 32), st.integers(8, 32))
def test_rglru_state_is_contraction(b, s, w):
    """|a_t| < 1 always: with zero input the state decays monotonically."""
    key = jax.random.PRNGKey(s * w)
    x = jnp.zeros((b, s, w))
    gx = jax.random.normal(key, (b, s, w))
    ga = jax.random.normal(key, (b, s, w))
    la = jax.random.normal(key, (w,))
    h0 = jnp.ones((b, w))
    seq, last = L.rglru(x, gx, ga, la, h0)
    seqs = jnp.abs(seq.astype(jnp.float32))
    assert bool(jnp.all(seqs[:, 0] <= 1.0 + 1e-5))
    assert bool(jnp.all(seqs[:, -1] <= seqs[:, 0] + 1e-5))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000),                      # sim seed
       st.integers(1, 3),                           # replication k
       st.floats(2.0, 20.0),                        # drive MTBF
       st.sampled_from([None, 4.0]),                # MTTR (None = fail-stop)
       st.sampled_from(["none", "fixed", "expo"]),  # retry policy
       st.booleans(),                               # repair on/off
       st.sampled_from([None, 0.2, 0.6]),           # timeout_s
       st.sampled_from([1, 2, 4]),                  # shard count
       st.sampled_from([None, "bucket", "shed", "push", "full"]))  # overload
def test_request_conservation_under_faults(seed, k, mtbf, mttr, retry,
                                           repair, timeout_s, n_shards,
                                           overload):
    """Every arrival ends exactly once — completed, abandoned, rejected,
    or shed — under arbitrary fault plans and any overload-control mix:
    retries never double-complete a request, the terminal states are
    mutually exclusive, ``arrivals == completed + abandoned + rejected +
    shed`` holds to the request, and the served busy-seconds stay within
    the fleet's physical capacity.  Holds under any shard count: sharded
    runs inject shard-local faults and run shard-local admission gates
    but must keep the fleet-wide books exact."""
    from repro.core.faults import (ExponentialBackoff, FaultPlan, FixedRetry,
                                   NoRetry, RepairModel)
    from repro.core.function import standard_pipeline
    from repro.core.overload import (Backpressure, Brownout, OverloadControl,
                                     ShedPolicy, TokenBucket)
    from repro.core.scheduler import ClusterSim
    from repro.core.arrivals import PoissonProcess
    from repro.core.tiering import TierConfig

    n_dscs, n_cpu, dur = 4, 4, 4.0
    fp = FaultPlan(
        drive_mtbf_s=mtbf, drive_mttr_s=mttr,
        stall_mtbf_s=8.0, stall_s=1.0,
        cpu_mtbf_s=3 * mtbf, cpu_mttr_s=mttr,
        backing_fail_p=0.1,
        retry={"none": NoRetry(), "fixed": FixedRetry(),
               "expo": ExponentialBackoff()}[retry],
        repair=RepairModel(bandwidth_bps=50e6) if repair else None,
        detect_timeout_s=0.15)
    ov = {
        None: None,
        "bucket": OverloadControl(admission=TokenBucket(rate=25.0,
                                                        burst=4.0)),
        "shed": OverloadControl(shed=ShedPolicy(max_queue=2,
                                                drop="incoming")),
        "push": OverloadControl(backpressure=Backpressure(target_depth=1.0)),
        "full": OverloadControl(
            admission=TokenBucket(rate=30.0, burst=2.0, per_class=True),
            shed=ShedPolicy(max_queue=3, hopeless=True,
                            codel_target_s=0.05),
            backpressure=Backpressure(target_depth=2.0),
            brownout=Brownout(on_depth=1.0, off_depth=0.25, min_epochs=1)),
    }[overload]
    sim = ClusterSim(n_dscs=n_dscs, n_cpu=n_cpu, seed=seed, faults=fp,
                     tier=TierConfig(replication_k=k, n_objects=32),
                     overload=ov)
    tr = sim.engine.run_sharded([standard_pipeline("asset_damage")],
                                arrivals=PoissonProcess(rate=60.0),
                                duration_s=dur, timeout_s=timeout_s,
                                n_shards=n_shards)
    fs = sim.fault_stats()
    completed = int(np.count_nonzero(tr.completed))
    abandoned = int(np.count_nonzero(tr.winner == -1))
    # terminal states are exclusive and exhaustive over the trace
    assert completed + abandoned == tr.n
    assert not np.any(tr.completed & (tr.winner == -1))
    # a completed request has exactly one winning path and a finite finish
    fin = tr.finish[tr.completed]
    assert np.all(np.isfinite(fin))
    assert np.all(tr.winner[tr.completed] >= 0)
    assert np.all(np.isnan(tr.finish[tr.winner == -1]))
    # fault_stats agrees with the trace (goodput never double-counts):
    # arrivals == completed + abandoned + rejected + shed
    assert fs["goodput"]["offered"] == tr.n
    assert fs["goodput"]["completed"] == completed
    assert (fs["abandoned"] + fs["deadline_abandoned"] + fs["rejected"]
            + fs["shed"]) == abandoned
    ost = sim.overload_stats()
    if ov is not None:
        assert ost["rejected"] == fs["rejected"]
        assert ost["shed"] == fs["shed"]
        assert ost["admitted"] + ost["rejected"] == tr.n
    else:
        assert ost is None
        assert fs["rejected"] == 0 and fs["shed"] == 0
    # busy seconds can't exceed fleet capacity over the run horizon
    ps = sim.engine.power_stats()
    horizon = float(ps["horizon"])
    assert -1e-9 <= float(ps["dscs"]["busy_s"]) <= n_dscs * horizon + 1e-6
    assert -1e-9 <= float(ps["cpu"]["busy_s"]) <= n_cpu * horizon + 1e-6


def test_metastability_admission_prevents_goodput_collapse():
    """The metastable-failure regression (ISSUE 10): past the saturation
    knee with exponential-backoff retries live, the unprotected fleet's
    SLA goodput collapses below 50% of what it sustains at the knee,
    while the admission-controlled fleet holds at least 90% of it."""
    from repro.core.arrivals import PoissonProcess
    from repro.core.faults import ExponentialBackoff, FaultPlan
    from repro.core.function import standard_pipeline
    from repro.core.overload import (Backpressure, Brownout, OverloadControl,
                                     ShedPolicy, TokenBucket)
    from repro.core.scheduler import ClusterSim

    pipes = [standard_pipeline("asset_damage")]
    sla_s, timeout_s, dur = 0.15, 0.5, 10.0
    knee = ClusterSim(n_dscs=4, n_cpu=4, seed=0).max_throughput(
        pipes, sla_s=sla_s, sla_frac=0.5, duration_s=8.0, hi=4096.0)
    fp = FaultPlan(drive_mtbf_s=20.0, drive_mttr_s=4.0,
                   retry=ExponentialBackoff(base_s=0.01, cap_s=0.5,
                                            max_attempts=8),
                   retry_budget=None, detect_timeout_s=0.2)
    ov = OverloadControl(admission=TokenBucket(rate=0.9 * knee, burst=8.0),
                         shed=ShedPolicy(max_queue=3, hopeless=True),
                         backpressure=Backpressure(target_depth=1.0),
                         brownout=Brownout(on_depth=1.2, off_depth=0.4))

    def goodput_per_s(rate, overload):
        sim = ClusterSim(n_dscs=4, n_cpu=4, seed=0, hedge_budget_s=0.05,
                         faults=fp, overload=overload)
        tr = sim.run(pipes, arrivals=PoissonProcess(rate=rate),
                     duration_s=dur, timeout_s=timeout_s)
        lat = np.array([r.latency for r in tr], dtype=float)
        comp = lat[~np.isnan(lat)]
        return float(np.count_nonzero(comp <= sla_s)) / dur

    at_knee = goodput_per_s(knee, None)
    storm = goodput_per_s(1.5 * knee, None)
    held = goodput_per_s(1.5 * knee, ov)
    assert storm < 0.5 * at_knee        # naive retry storm: collapse
    assert held >= 0.9 * at_knee        # admission + shedding: graceful


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000),     # draw seed
       st.integers(1, 20),         # server count
       st.integers(0, 300),        # request count (0 = fully empty)
       st.booleans())              # skew everything onto one server
def test_segmented_lindley_matches_per_queue_oracle(seed, nserv, n, skew):
    """The length-bucketed segmented solver is exactly (``==``, not
    allclose) the per-queue `_fcfs_segment` oracle for arbitrary
    ``(keys, t, s)`` — including empty segments and the single-server
    skew that used to blow up the dense pad — and the vectorized
    depth-max equals the per-server scalar loop."""
    from repro.core import lindley
    from repro.core.sharding import _fcfs_segment, _queue_depth_max

    rng = np.random.default_rng(seed)
    keys = (np.zeros(n, dtype=np.int64) if skew
            else np.sort(rng.integers(0, nserv, size=n)))
    t = rng.uniform(0.0, 50.0, size=n)
    s = rng.uniform(1e-3, 5.0, size=n)
    seg = lindley.segment_fenceposts(keys, 0, nserv)
    for j in range(nserv):                 # arrivals sorted per segment
        t[seg[j]:seg[j + 1]].sort()
    start = np.empty(n)
    fin = np.empty(n)
    lindley.solve_segments(seg, t, s, start, fin, backend="segmented")
    maxd = lindley.queue_depth_max(seg, start, t)
    for j in range(nserv):
        a, b = int(seg[j]), int(seg[j + 1])
        if a == b:
            assert maxd[j] == 0
            continue
        st_ref, fin_ref = _fcfs_segment(t[a:b], s[a:b])
        assert start[a:b].tobytes() == st_ref.tobytes()
        assert fin[a:b].tobytes() == fin_ref.tobytes()
        assert maxd[j] == _queue_depth_max(start[a:b], t[a:b])


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([32, 64, 128]), st.sampled_from([16, 32, 64]))
def test_ssd_chunk_invariance(s, chunk):
    """SSD output must not depend on the chunk size."""
    key = jax.random.PRNGKey(s + chunk)
    ks = jax.random.split(key, 5)
    B, H, P, G, N = 1, 2, 8, 1, 8
    x = jax.random.normal(ks[0], (B, s, H, P)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, s, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, s, G, N)) * 0.3
    y1, h1 = L.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y2, h2 = L.ssd_chunked(x, dt, A, Bm, Cm, chunk=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-3, atol=2e-3)
