"""Fault injection & failure recovery (ISSUE 7): drive/node failures,
retry-with-backoff, replica repair, deadline abandonment, and the fig23
availability gate.

PYTHONPATH=src python -m pytest -q tests/test_faults.py
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.arrivals import PoissonProcess, make_arrivals
from repro.core.autoscale import ReactivePolicy, StaticPolicy, evaluate_policy
from repro.core.faults import (CpuCrash, DriveFailure, DriveStall,
                               ExponentialBackoff, FaultPlan, FixedRetry,
                               NoRetry, RepairModel, RetryBudget)
from repro.core.function import standard_pipeline
from repro.core.scheduler import ClusterSim
from repro.core.tiering import TierConfig

PIPES = [standard_pipeline(n) for n in ("asset_damage", "content_moderation")]


def _trace(sim, *, rate=80.0, dur=8.0, timeout_s=None, seed_pipes=PIPES):
    return sim.engine.run_soa(seed_pipes, arrivals=PoissonProcess(rate=rate),
                              duration_s=dur, timeout_s=timeout_s)


# ---------------------------------------------------------------------------
# plan construction & validation
# ---------------------------------------------------------------------------

def test_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(drive_mtbf_s=-1.0).validate()
    with pytest.raises(ValueError):
        FaultPlan(backing_fail_p=1.5).validate()
    with pytest.raises(ValueError):
        FaultPlan(events=(DriveFailure(time=-1.0, drive=0),)).validate()
    with pytest.raises(ValueError):
        RepairModel(bandwidth_bps=0.0).validate()
    FaultPlan(repair=RepairModel()).validate()      # repair-only plan is fine


def test_timeline_sorted_and_bounded():
    fp = FaultPlan(drive_mtbf_s=2.0, drive_mttr_s=1.0, stall_mtbf_s=3.0,
                   cpu_mtbf_s=4.0, cpu_mttr_s=2.0)
    rng = np.random.default_rng(0)
    tl = fp.timeline(4, 4, 20.0, rng)
    times = [e[0] for e in tl]
    assert times == sorted(times)
    assert all(t >= 0.0 for t in times)
    # begin events all fall inside the horizon (recoveries may overhang)
    from repro.core.faults import CPU_CRASH, DRIVE_FAIL, STALL_BEGIN
    assert all(t < 20.0 for t, k, _, _ in tl
               if k in (DRIVE_FAIL, STALL_BEGIN, CPU_CRASH))


def test_timeline_out_of_range_event_raises():
    fp = FaultPlan(events=(DriveFailure(time=1.0, drive=9),))
    with pytest.raises(ValueError):
        fp.timeline(4, 4, 10.0, np.random.default_rng(0))


def test_retry_policy_semantics():
    rng = np.random.default_rng(0)
    assert NoRetry().delay_s(1, 0.0, rng) is None
    fr = FixedRetry(delay=0.05, max_attempts=3)
    assert fr.delay_s(3, 0.0, rng) == pytest.approx(0.05)
    assert fr.delay_s(4, 0.0, rng) is None
    eb = ExponentialBackoff(base_s=0.02, cap_s=1.0, max_attempts=6)
    prev = 0.0
    for att in range(1, 7):
        d = eb.delay_s(att, prev, rng)
        assert 0.02 <= d <= 1.0         # decorrelated jitter stays in range
        prev = d
    assert eb.delay_s(7, prev, rng) is None


def test_retry_budget_circuit_breaker():
    b = RetryBudget(ratio=0.1, min_tokens=2)
    assert b.allows(0, 0)
    assert b.allows(1, 0)
    assert not b.allows(2, 0)           # min tokens exhausted
    assert b.allows(11, 100)            # 2 + 10 tokens at 100 arrivals
    assert not b.allows(12, 100)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def test_empty_plan_runs_clean():
    sim = ClusterSim(n_dscs=4, n_cpu=4, seed=0, faults=FaultPlan())
    tr = _trace(sim)
    fs = sim.fault_stats()
    assert fs["enabled"]
    assert sum(fs["injected"].values()) == 0
    assert fs["goodput"]["goodput_frac"] == 1.0
    assert int(np.count_nonzero(tr.completed)) == tr.n


def test_faulted_run_is_deterministic():
    fp = FaultPlan(drive_mtbf_s=3.0, drive_mttr_s=5.0, stall_mtbf_s=4.0,
                   cpu_mtbf_s=6.0, cpu_mttr_s=4.0, backing_fail_p=0.1,
                   repair=RepairModel(), detect_timeout_s=0.2)
    traces, stats = [], []
    for _ in range(2):
        sim = ClusterSim(n_dscs=4, n_cpu=4, seed=21, faults=fp,
                         tier=TierConfig(replication_k=2, n_objects=64))
        traces.append(_trace(sim, dur=10.0))
        stats.append(sim.fault_stats())
    a, b = traces
    for f in ("arrival", "finish", "winner", "drive", "start", "service",
              "hedged"):
        assert np.array_equal(getattr(a, f), getattr(b, f), equal_nan=True), f
    assert stats[0] == stats[1]


def test_drive_failstop_loses_inflight_and_retry_recovers():
    fp_none = FaultPlan(events=(DriveFailure(time=1.0, drive=0),),
                        retry=NoRetry())
    sim = ClusterSim(n_dscs=2, n_cpu=8, seed=13, faults=fp_none)
    _trace(sim, rate=300.0)
    fs = sim.fault_stats()
    assert fs["injected"]["drive_fail"] == 1
    assert fs["lost"] > 0
    assert fs["abandoned"] > 0          # no retry: lost => abandoned
    assert fs["goodput"]["goodput_frac"] < 1.0

    fp_retry = FaultPlan(events=(DriveFailure(time=1.0, drive=0),),
                         retry=ExponentialBackoff(),
                         retry_budget=RetryBudget(ratio=1.0, min_tokens=1024))
    sim2 = ClusterSim(n_dscs=2, n_cpu=8, seed=13, faults=fp_retry)
    _trace(sim2, rate=300.0)
    fs2 = sim2.fault_stats()
    assert fs2["retries"]["scheduled"] > 0
    assert fs2["abandoned"] < fs["abandoned"]
    assert (fs2["goodput"]["goodput_frac"]
            > fs["goodput"]["goodput_frac"])


def test_degrades_to_cpu_when_home_drive_dead():
    # the only drive dies and never recovers: accelerable requests must
    # gracefully degrade to the CPU path + backing fetch, not be dropped
    fp = FaultPlan(events=(DriveFailure(time=0.5, drive=0),))
    sim = ClusterSim(n_dscs=1, n_cpu=8, seed=0, faults=fp)
    tr = _trace(sim, rate=40.0, dur=6.0)
    fs = sim.fault_stats()
    assert fs["degraded"] > 0
    assert fs["goodput"]["goodput_frac"] == 1.0
    late = tr.winner[tr.arrival > 1.0]
    assert np.all(late == 1)            # everything after the loss is CPU-won


def test_transient_failure_recovers_service():
    fp = FaultPlan(events=(DriveFailure(time=1.0, drive=0, down_s=2.0),))
    sim = ClusterSim(n_dscs=1, n_cpu=4, seed=0, faults=fp)
    tr = _trace(sim, rate=30.0, dur=8.0)
    fs = sim.fault_stats()
    assert fs["injected"]["drive_recover"] == 1
    assert fs["unavailability"]["total_s"] == pytest.approx(2.0)
    # post-recovery accelerable arrivals run on the drive again
    assert np.any(tr.winner[tr.arrival > 3.5] == 0)


def test_stall_plus_detection_hedges():
    fp = FaultPlan(events=(DriveStall(time=0.5, drive=0, duration_s=4.0,
                                      factor=50.0),),
                   detect_timeout_s=0.1)
    sim = ClusterSim(n_dscs=1, n_cpu=4, seed=0, faults=fp)
    _trace(sim, rate=30.0, dur=5.0)
    fs = sim.fault_stats()
    assert fs["injected"]["stall"] == 1
    assert fs["detect_hedges"] > 0      # stalled requests were hedged
    assert fs["goodput"]["goodput_frac"] == 1.0


def test_cpu_crash_never_kills_last_node():
    fp = FaultPlan(cpu_mtbf_s=0.5, cpu_mttr_s=None)
    sim = ClusterSim(n_dscs=2, n_cpu=2, seed=0, faults=fp)
    _trace(sim, rate=40.0, dur=6.0)
    fs = sim.fault_stats()
    assert fs["injected"]["cpu_crash"] == 1         # only n_cpu - 1 may die
    assert fs["injected"]["cpu_crash_skipped"] > 0
    assert fs["goodput"]["goodput_frac"] == 1.0


def test_repair_rereplicates_lost_objects():
    tier = TierConfig(replication_k=2, n_objects=64)
    fp = FaultPlan(events=(DriveFailure(time=2.0, drive=1),),
                   repair=RepairModel(bandwidth_bps=50e6))
    sim = ClusterSim(n_dscs=4, n_cpu=4, seed=21, faults=fp, tier=tier)
    _trace(sim, dur=10.0)
    fs = sim.fault_stats()
    assert fs["repair"]["jobs"] == 1
    assert fs["repair"]["objects"] > 0
    assert fs["repair"]["bytes"] > 0
    assert fs["repair"]["seconds"] == pytest.approx(
        fs["repair"]["bytes"] / 50e6)


def test_timeout_abandons_and_counts():
    sim = ClusterSim(n_dscs=2, n_cpu=2, seed=13)
    tr = _trace(sim, rate=200.0, dur=5.0, timeout_s=0.3)
    fs = sim.fault_stats()
    assert not fs["enabled"]            # deadline-only: fault layer off
    assert fs["deadline_abandoned"] > 0
    aband = int(np.count_nonzero(tr.winner == -1))
    comp = int(np.count_nonzero(tr.completed))
    assert aband == fs["deadline_abandoned"]
    assert comp + aband == tr.n         # conservation, no in-flight (drained)
    assert np.all(np.isnan(tr.finish[tr.winner == -1]))
    assert sim.telemetry.get("deadline_abandoned") == aband


def test_timeout_validation():
    sim = ClusterSim(n_dscs=2, n_cpu=2, seed=0)
    with pytest.raises(ValueError):
        _trace(sim, timeout_s=0.0)


def test_fault_stats_none_without_plan_or_timeout():
    sim = ClusterSim(n_dscs=2, n_cpu=2, seed=0)
    _trace(sim, rate=20.0, dur=2.0)
    assert sim.fault_stats() is None


# ---------------------------------------------------------------------------
# autoscaler composition (satellite: power-down charges repair)
# ---------------------------------------------------------------------------

def test_autoscaler_power_down_charges_repair():
    tier_kw = dict(replication_k=2, n_objects=64)
    fp = FaultPlan(repair=RepairModel(bandwidth_bps=100e6))
    kw = dict(arrivals=make_arrivals("diurnal", 40.0, period_s=8.0),
              duration_s=16.0, n_dscs=6, n_cpu=6, sla_s=0.6, seed=3)
    scaled = evaluate_policy(ReactivePolicy(min_dscs_on=0), PIPES,
                             tier=TierConfig(**tier_kw), faults=fp, **kw)
    static = evaluate_policy(StaticPolicy(6, 6), PIPES,
                             tier=TierConfig(**tier_kw), faults=fp, **kw)
    assert scaled.repair_gb > 0.0       # power-downs re-replicate
    assert static.repair_gb == 0.0      # full fleet never powers down
    # and the repair traffic lands in the cost scorecard
    from repro.core.autoscale import fleet_cost_usd
    ps = {"cpu": {"powered_s": 0.0}, "dscs": {"powered_s": 0.0}}
    c = fleet_cost_usd(ps, 0.0, repair_bytes=5e9)
    assert c["repair"] == pytest.approx(0.1)        # 5 GB * $0.02
    assert c["total"] == pytest.approx(c["repair"])


# ---------------------------------------------------------------------------
# timeout/overload-only telemetry (ISSUE 10 satellite regression)
# ---------------------------------------------------------------------------

def test_timeout_only_run_surfaces_deadline_telemetry():
    """A run with a deadline but no FaultPlan must still expose the full
    ``fault_stats()`` goodput schema — deadline-abandon counts and the
    overload-layer rejection/shed counters (zero when the layer is off) —
    in both the single-engine and the sharded-merge paths."""
    sim = ClusterSim(n_dscs=3, n_cpu=3, seed=3)         # no FaultPlan
    tr = _trace(sim, rate=250.0, dur=4.0, timeout_s=0.06)
    fs = sim.fault_stats()
    assert fs is not None and fs["enabled"] is False
    assert fs["deadline_abandoned"] > 0
    assert fs["abandoned"] == 0
    assert fs["rejected"] == 0 and fs["shed"] == 0
    assert fs["goodput"]["offered"] == tr.n

    sh = ClusterSim(n_dscs=4, n_cpu=4, seed=3)
    str_ = sh.run_sharded(PIPES, arrivals=PoissonProcess(rate=250.0),
                          duration_s=4.0, n_shards=2, timeout_s=0.06)
    sfs = sh.fault_stats()
    assert sfs is not None and sfs["enabled"] is False
    assert sfs["deadline_abandoned"] > 0
    assert sfs["rejected"] == 0 and sfs["shed"] == 0
    assert sfs["goodput"]["offered"] == str_.n


def test_overload_rejections_surface_in_fault_stats():
    """Overload-layer rejections/sheds land in ``fault_stats()`` even
    without a FaultPlan, so goodput accounting stays exact."""
    from repro.core.overload import OverloadControl, ShedPolicy, TokenBucket
    ov = OverloadControl(admission=TokenBucket(rate=30.0, burst=2.0),
                         shed=ShedPolicy(max_queue=2))
    sim = ClusterSim(n_dscs=3, n_cpu=3, seed=3, overload=ov)
    tr = _trace(sim, rate=250.0, dur=4.0)
    fs = sim.fault_stats()
    assert fs is not None and fs["rejected"] > 0
    dead = int(np.count_nonzero(tr.winner == -1))
    assert (fs["abandoned"] + fs["deadline_abandoned"] + fs["rejected"]
            + fs["shed"]) == dead
    assert fs["goodput"]["completed"] + dead == tr.n


# ---------------------------------------------------------------------------
# benchmarks/run.py regression + fig23 gate
# ---------------------------------------------------------------------------

def test_run_py_exits_nonzero_on_figure_failure(monkeypatch, capsys):
    import benchmarks.figures as figures_mod
    from benchmarks import run as run_mod

    def fig99_boom():
        raise RuntimeError("mid-sweep failure")

    def fig98_fine():
        return [("fig98/ok", 1.0, "")]

    monkeypatch.setattr(figures_mod, "ALL_FIGURES", [fig98_fine, fig99_boom])
    with pytest.raises(SystemExit) as ei:
        run_mod.main(["--only", "fig9", "--json"])
    assert "fig99_boom" in str(ei.value)
    # the JSON already emitted stays valid for the figures that did run
    import json
    out = capsys.readouterr().out
    envelope = json.loads(out[out.index("{"):])
    assert envelope["schema"] == "figures/v2"
    assert any(r["name"] == "fig98/ok" for r in envelope["rows"])


def test_fig23_smoke_headline_gate(monkeypatch):
    import benchmarks.figures as figures_mod
    monkeypatch.setattr(figures_mod, "SMOKE", True)
    rows = figures_mod.fig23_availability()
    by_name = {n: v for n, v, _ in rows}
    gain = by_name["fig23/headline/sla_gain"]
    assert gain >= 2.0                  # the CI-gated acceptance criterion
    assert by_name["fig23/expo_k2_repair/sla_frac"] > \
        by_name["fig23/none_k1/sla_frac"]
    assert 0.0 < by_name["fig23/none_k1/sla_frac"] < 1.0
