"""Tiered data layer (tiering.py + the engine's tier path): per-drive
DRAM caches, k-way replica routing, backing-store fills, hot-key
migration — and the bit-exactness guarantee when the tier is disabled."""
import numpy as np
import pytest

from repro.core.arrivals import PoissonProcess
from repro.core.engine import ClusterEngine
from repro.core.function import standard_pipeline
from repro.core.placement import StoragePool
from repro.core.scheduler import ClusterSim
from repro.core.tenancy import TenantSpec, WeightedTimeSlice
from repro.core.tiering import (DriveCache, MigrationController,
                                MigrationPolicy, TierConfig,
                                build_replica_table, zipf_object_ids)

PIPES = [standard_pipeline("content_moderation"),
         standard_pipeline("credit_risk")]


# ---------------------------------------------------------------- DriveCache
def test_cache_lru_eviction_order():
    c = DriveCache(capacity_bytes=300)
    for k in (0, 1, 2):
        assert not c.access(k, 100)     # cold misses, all admitted
    assert c.access(0, 100)             # hit refreshes 0 to MRU
    c.access(3, 100)                    # evicts LRU = 1
    assert 0 in c and 2 in c and 3 in c and 1 not in c
    assert c.used_bytes == 300
    assert c.evictions == 1


def test_cache_frequency_admission():
    c = DriveCache(capacity_bytes=100, admit_after=2)
    assert not c.access(7, 50)          # first sighting: not admitted
    assert 7 not in c
    assert not c.access(7, 50)          # second sighting: admitted (miss)
    assert 7 in c
    assert c.access(7, 50)              # now a hit
    s = c.stats()
    assert s["hits"] == 1 and s["misses"] == 2 and s["rejected"] == 1


def test_cache_warm_peek_does_not_mutate():
    c = DriveCache(capacity_bytes=200)
    c.access(0, 100)
    c.access(1, 100)
    assert c.warm(0) and c.warm(1) and not c.warm(2)
    # warm() peeks: LRU order stays 0 (oldest), 1 — inserting evicts 0
    c.warm(0)
    c.access(2, 100)
    assert 0 not in c and 1 in c


def test_cache_oversize_object_never_admitted():
    c = DriveCache(capacity_bytes=100)
    assert not c.access(0, 101)
    assert 0 not in c and c.used_bytes == 0


# ------------------------------------------------- Zipf + replica table
def test_zipf_object_ids_skew_and_determinism():
    rng1 = np.random.default_rng(3)
    rng2 = np.random.default_rng(3)
    a = zipf_object_ids(20_000, 64, 1.2, rng1)
    b = zipf_object_ids(20_000, 64, 1.2, rng2)
    assert np.array_equal(a, b)
    assert a.min() >= 0 and a.max() < 64
    counts = np.bincount(a, minlength=64)
    assert counts[0] == counts.max()    # object 0 is the hottest
    assert counts[0] > 0.15 * a.size    # s=1.2 top share ~25%
    # uniform (s=0) is far flatter
    flat = zipf_object_ids(20_000, 64, 0.0, np.random.default_rng(3))
    assert np.bincount(flat, minlength=64).max() < counts[0]


def test_replica_table_matches_storage_pool_hrw():
    nd, k = 6, 3
    table = build_replica_table(32, nd, k)
    pool = StoragePool(n_plain=2, n_dscs=nd)
    dscs = pool.dscs_drives()
    for o, reps in enumerate(table):
        assert len(reps) == k and len(set(reps)) == k
        want = [dscs.index(d) for d in pool.replicas(f"obj-{o}", k)]
        assert reps == want


def test_migration_controller_plans_hot_to_cold():
    mc = MigrationController(MigrationPolicy(max_moves_per_epoch=2,
                                             min_queue_imbalance=3))
    replicas = [[0], [0], [2]]
    access = [{0: 10, 1: 4}, {}, {2: 1}, {}]
    moves = mc.plan(1.0, [8, 0, 1, 0], [1, 0, 0, 0], access, replicas)
    # hottest key first, to the coldest drive not already holding it
    assert moves == [(0, 0, 1), (1, 0, 1)]
    assert mc.moves == 2
    # below the imbalance threshold: no moves
    assert mc.plan(2.0, [2, 0, 1, 0], [0, 0, 0, 0], access, replicas) == []


def test_tier_config_validation():
    with pytest.raises(ValueError):
        TierConfig(replication_k=0).validate()
    with pytest.raises(ValueError):
        TierConfig(cache_bytes=-1).validate()
    with pytest.raises(ValueError):
        MigrationPolicy(epoch_s=0.0).validate()
    assert not TierConfig().enabled
    assert TierConfig(replication_k=2).enabled
    assert TierConfig(cache_bytes=1).enabled
    assert TierConfig(migration=MigrationPolicy()).enabled


# ------------------------------------------------------- engine integration
def test_disabled_tier_bit_identical_to_no_tier():
    """A None tier and a disabled TierConfig take the same code path:
    identical rng streams, event order and RequestResult columns."""
    for seed in (13, 21):
        arr = PoissonProcess(rate=150.0)
        t1 = ClusterEngine(n_dscs=4, n_cpu=6, seed=seed,
                           hedge_budget_s=0.25).run_soa(
            PIPES, arrivals=arr, duration_s=5.0)
        eng = ClusterEngine(n_dscs=4, n_cpu=6, seed=seed,
                            hedge_budget_s=0.25, tier=TierConfig())
        t2 = eng.run_soa(PIPES, arrivals=arr, duration_s=5.0)
        for f in ("arrival", "finish", "winner", "drive", "start",
                  "service", "hedged", "dscs_finish", "cpu_finish"):
            a, b = getattr(t1, f), getattr(t2, f)
            assert np.array_equal(a, b, equal_nan=(a.dtype.kind == "f"))
        assert eng.tier_stats() is None


def test_replication_routes_within_replica_sets():
    nobj, nd, k = 32, 4, 2
    tier = TierConfig(replication_k=k, n_objects=nobj, zipf_s=1.1)
    eng = ClusterEngine(n_dscs=nd, n_cpu=4, seed=5, tier=tier)
    trace = eng.run_soa(PIPES, arrivals=PoissonProcess(rate=150.0),
                        duration_s=5.0)
    table = build_replica_table(nobj, nd, k)
    # reconstruct the object draws: same child rng stream as the engine's
    kids = np.random.SeedSequence(5).spawn(3)
    objs = zipf_object_ids(trace.n, nobj, 1.1, np.random.default_rng(kids[2]))
    dscs_served = trace.winner == 0
    assert int(dscs_served.sum()) > 0
    for rid in np.flatnonzero(dscs_served):
        assert int(trace.drive[rid]) in table[int(objs[rid])]


def test_replication_spreads_hot_object_and_cuts_p99():
    """One Zipf-hot object saturates a single drive at k=1; k=2 plus a
    warm cache must spread it and cut the hot-drive p99 (the fig22
    claim, at test scale)."""
    pipes = [standard_pipeline("asset_damage")]
    arr = PoissonProcess(rate=76.0)
    kw = dict(n_dscs=8, n_cpu=8, seed=0)

    def hot_p99(tier):
        trace = ClusterEngine(tier=tier, **kw).run_soa(
            pipes, arrivals=arr, duration_s=12.0)
        drv = trace.drive
        hot = np.argmax(np.bincount(drv[drv >= 0], minlength=8))
        lat = trace.latency[drv == hot]
        return float(np.percentile(lat, 99))

    base = hot_p99(TierConfig(replication_k=1, n_objects=256, zipf_s=1.2))
    tiered = hot_p99(TierConfig(replication_k=2, cache_bytes=64 << 20,
                                admit_after=2, n_objects=256, zipf_s=1.2))
    assert tiered < base / 2


def test_cache_hits_recorded_and_shorten_service():
    tier = TierConfig(cache_bytes=256 << 20, n_objects=8, zipf_s=1.0)
    eng = ClusterEngine(n_dscs=2, n_cpu=2, seed=3, tier=tier)
    eng.run_soa(PIPES, arrivals=PoissonProcess(rate=100.0), duration_s=4.0)
    st = eng.tier_stats()
    assert st["cache"]["hits"] > 0
    assert 0.0 < st["cache"]["hit_rate"] <= 1.0
    assert eng.telemetry.get("cache_hits") == st["cache"]["hits"]
    # hits shorten the mean DSCS service vs the cache-less run
    no_cache = ClusterEngine(n_dscs=2, n_cpu=2, seed=3,
                             tier=TierConfig(n_objects=8, zipf_s=1.0))
    ta = eng.run_soa(PIPES, arrivals=PoissonProcess(rate=100.0),
                     duration_s=4.0)
    tb = no_cache.run_soa(PIPES, arrivals=PoissonProcess(rate=100.0),
                          duration_s=4.0)
    da, db = ta.winner == 0, tb.winner == 0
    assert float(ta.service[da].mean()) < float(tb.service[db].mean())


def test_secondary_replicas_pay_backing_fetch():
    # k=2: routed-to secondaries materialize lazily from the backing store
    tier = TierConfig(replication_k=2, n_objects=16, zipf_s=1.0)
    eng = ClusterEngine(n_dscs=4, n_cpu=4, seed=11, tier=tier)
    eng.run_soa(PIPES, arrivals=PoissonProcess(rate=200.0), duration_s=4.0)
    st = eng.tier_stats()
    assert 0 < st["backing_fetches"] <= 16   # at most one fill per replica
    assert st["backing_s"] > 0.0


def test_migration_moves_hot_keys_off_saturated_drive():
    tier = TierConfig(n_objects=16, zipf_s=1.5,
                      migration=MigrationPolicy(epoch_s=0.5,
                                                min_queue_imbalance=2))
    eng = ClusterEngine(n_dscs=4, n_cpu=4, seed=7, tier=tier)
    eng.run_soa(PIPES, arrivals=PoissonProcess(rate=300.0), duration_s=5.0)
    st = eng.tier_stats()
    mg = st["migration"]
    assert mg["moves"] > 0 and mg["epochs"] > 0
    assert len(mg["log"]) == mg["moves"]
    for t, obj, frm, to in mg["log"]:
        assert frm != to and 0 <= obj < 16
    # migrated-to drives fill from the backing store on first access
    assert st["backing_fetches"] > 0


def test_tier_composes_with_multi_tenant_fcfs():
    tenants = [
        TenantSpec("a", tuple(PIPES), PoissonProcess(rate=50.0),
                   sla_s=0.5, weight=1.0),
        TenantSpec("b", tuple(PIPES), PoissonProcess(rate=50.0),
                   sla_s=1.0, weight=1.0),
    ]
    sim = ClusterSim(n_dscs=4, n_cpu=4, seed=0,
                     tier=TierConfig(replication_k=2, cache_bytes=64 << 20,
                                     n_objects=32))
    trace, reps = sim.run_tenants(tenants, duration_s=4.0)
    assert len(reps) == 2 and trace.n > 0
    assert sim.tier_stats()["cache"]["hits"] > 0


def test_tier_rejects_non_fcfs_schedulers():
    tenants = [TenantSpec("a", tuple(PIPES), PoissonProcess(rate=20.0),
                          sla_s=0.5, weight=1.0)]
    sim = ClusterSim(n_dscs=2, n_cpu=2, seed=0,
                     tier=TierConfig(replication_k=2, n_objects=8))
    with pytest.raises(NotImplementedError, match="FCFS"):
        sim.run_tenants(tenants, duration_s=2.0,
                        scheduler=WeightedTimeSlice(quantum_s=0.01,
                                                    switch_s=0.001))


def test_tier_composes_with_autoscaling():
    from repro.core.autoscale import ReactivePolicy, evaluate_policy
    rep = evaluate_policy(
        ReactivePolicy(), PIPES, arrivals=PoissonProcess(rate=100.0),
        duration_s=6.0, n_dscs=4, n_cpu=6, sla_s=0.6, seed=2,
        tier=TierConfig(replication_k=2, cache_bytes=64 << 20, n_objects=32))
    assert rep.n_requests > 0
    assert 0.0 <= rep.sla_frac <= 1.0
