"""Per-kernel shape/dtype sweeps: pallas_call (interpret) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (64, 128, 256), (8, 16, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["none", "relu", "gelu"])
def test_systolic_matmul(m, k, n, dtype, act):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (m, k), jnp.float32).astype(dtype)
    w = jax.random.normal(k2, (k, n), jnp.float32).astype(dtype)
    b = jax.random.normal(k3, (n,), jnp.float32).astype(dtype)
    got = ops.matmul(x, w, b, act=act, bm=min(64, m), bn=min(64, n),
                     bk=min(64, k))
    want = ref.matmul_ref(x, w, b, act=act)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.05 if dtype == jnp.bfloat16 else 1e-4,
                               atol=_tol(dtype) * max(1, k // 64))


def test_matmul_padded_arbitrary_shapes():
    x = jax.random.normal(KEY, (37, 147))
    w = jax.random.normal(KEY, (147, 53))
    got = ops.matmul_padded(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("b,h,kv,sq,skv,d", [
    (2, 8, 2, 128, 128, 64), (1, 4, 1, 64, 128, 32), (2, 4, 4, 128, 64, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
def test_flash_attention(b, h, kv, sq, skv, d, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, kv, skv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, kv, skv, d), jnp.float32)
    got = ops.attention(q, k, v, causal=causal, window=window, bq=32, bk=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtype(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 64, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 64, 32)).astype(dtype)
    got = ops.attention(q, k, v, bq=32, bk=32)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.05, atol=0.03)


@pytest.mark.parametrize("m,n", [(256, 256), (64, 384), (8, 128)])
@pytest.mark.parametrize("act", ["silu", "sigmoid", "tanh"])
def test_vector_engine_affine(m, n, act):
    x = jax.random.normal(KEY, (m, n))
    s = jax.random.normal(KEY, (n,))
    b = jax.random.normal(KEY, (n,))
    got = ops.affine_act(x, s, b, act=act)
    want = ref.affine_act_ref(x, s, b, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_vector_engine_quant_roundtrip():
    x = jax.random.normal(KEY, (128, 256)) * 3.0
    q, s = ops.quantize(x)
    qr, sr = ref.quantize_int8_ref(x)
    assert int(jnp.sum(jnp.abs(q.astype(jnp.int32) - qr.astype(jnp.int32)))) == 0
    xd = ops.dequantize(q, s)
    # int8 symmetric quantization error bound: scale/2 per element
    assert float(jnp.max(jnp.abs(xd - x))) <= float(jnp.max(s)) * 0.51


@pytest.mark.parametrize("b,s,w", [(2, 64, 128), (4, 128, 256), (1, 32, 128)])
@pytest.mark.slow
def test_rglru_kernel(b, s, w):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, w)) * 0.2
    gx = jax.random.normal(ks[1], (b, s, w))
    ga = jax.random.normal(ks[2], (b, s, w))
    la = jax.random.normal(ks[3], (w,))
    h0 = jax.random.normal(ks[0], (b, w)) * 0.1
    got = ops.rglru(x, gx, ga, la, h0)
    want = ref.rglru_ref(x, gx, ga, la, h0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 128, 4, 32, 2, 16, 32), (1, 256, 2, 16, 1, 8, 64),
    (2, 64, 4, 16, 4, 16, 64)])
@pytest.mark.slow
def test_ssd_kernel(b, s, h, p, g, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.4
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.4)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    y, hf = ops.ssd(x, dt, A, Bm, Cm, chunk=chunk)
    yr, hfr = ref.ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfr),
                               rtol=1e-3, atol=1e-3)
