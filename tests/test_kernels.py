"""Per-kernel shape/dtype sweeps: pallas_call (interpret) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (64, 128, 256), (8, 16, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["none", "relu", "gelu"])
def test_systolic_matmul(m, k, n, dtype, act):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (m, k), jnp.float32).astype(dtype)
    w = jax.random.normal(k2, (k, n), jnp.float32).astype(dtype)
    b = jax.random.normal(k3, (n,), jnp.float32).astype(dtype)
    got = ops.matmul(x, w, b, act=act, bm=min(64, m), bn=min(64, n),
                     bk=min(64, k))
    want = ref.matmul_ref(x, w, b, act=act)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.05 if dtype == jnp.bfloat16 else 1e-4,
                               atol=_tol(dtype) * max(1, k // 64))


def test_matmul_padded_arbitrary_shapes():
    x = jax.random.normal(KEY, (37, 147))
    w = jax.random.normal(KEY, (147, 53))
    got = ops.matmul_padded(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("b,h,kv,sq,skv,d", [
    (2, 8, 2, 128, 128, 64), (1, 4, 1, 64, 128, 32), (2, 4, 4, 128, 64, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
def test_flash_attention(b, h, kv, sq, skv, d, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, kv, skv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, kv, skv, d), jnp.float32)
    got = ops.attention(q, k, v, causal=causal, window=window, bq=32, bk=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtype(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 64, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 64, 32)).astype(dtype)
    got = ops.attention(q, k, v, bq=32, bk=32)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.05, atol=0.03)


@pytest.mark.parametrize("m,n", [(256, 256), (64, 384), (8, 128)])
@pytest.mark.parametrize("act", ["silu", "sigmoid", "tanh"])
def test_vector_engine_affine(m, n, act):
    x = jax.random.normal(KEY, (m, n))
    s = jax.random.normal(KEY, (n,))
    b = jax.random.normal(KEY, (n,))
    got = ops.affine_act(x, s, b, act=act)
    want = ref.affine_act_ref(x, s, b, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_vector_engine_quant_roundtrip():
    x = jax.random.normal(KEY, (128, 256)) * 3.0
    q, s = ops.quantize(x)
    qr, sr = ref.quantize_int8_ref(x)
    assert int(jnp.sum(jnp.abs(q.astype(jnp.int32) - qr.astype(jnp.int32)))) == 0
    xd = ops.dequantize(q, s)
    # int8 symmetric quantization error bound: scale/2 per element
    assert float(jnp.max(jnp.abs(xd - x))) <= float(jnp.max(s)) * 0.51


@pytest.mark.parametrize("b,s,w", [(2, 64, 128), (4, 128, 256), (1, 32, 128)])
@pytest.mark.slow
def test_rglru_kernel(b, s, w):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, w)) * 0.2
    gx = jax.random.normal(ks[1], (b, s, w))
    ga = jax.random.normal(ks[2], (b, s, w))
    la = jax.random.normal(ks[3], (w,))
    h0 = jax.random.normal(ks[0], (b, w)) * 0.1
    got = ops.rglru(x, gx, ga, la, h0)
    want = ref.rglru_ref(x, gx, ga, la, h0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# Lindley tests run in the CI kernel-smoke step: keep them small and
# NOT slow-marked.
@pytest.mark.parametrize("r,w", [(3, 17), (128, 128), (200, 300), (1, 1)])
def test_lindley_kernel_vs_ref(r, w):
    rng = np.random.default_rng(11)
    t = np.sort(rng.uniform(0.0, 100.0, size=(r, w)), axis=1)
    s = rng.uniform(1e-3, 4.0, size=(r, w))
    got = np.asarray(ops.lindley(t, s))
    from jax.experimental import enable_x64
    with enable_x64():
        want = np.asarray(ref.lindley_ref(jnp.asarray(t), jnp.asarray(s)))
    assert got.dtype == np.float64
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("seed", [0, 7])
@pytest.mark.parametrize("nserv,n", [(6, 500), (1, 700), (40, 64)])
def test_lindley_kernel_bit_equal_to_numpy_backend(seed, nserv, n):
    """Interpret-mode Pallas output must be byte-for-byte the segmented
    numpy backend (same fp64 ops in the same order) — the property that
    lets ``backend='pallas'`` reuse the golden traces unchanged."""
    from repro.core import lindley as core_lindley

    rng = np.random.default_rng(seed)
    keys = np.sort(rng.integers(0, nserv, size=n))
    t = rng.uniform(0.0, 60.0, size=n)
    seg = core_lindley.segment_fenceposts(keys, 0, nserv)
    for j in range(nserv):
        t[seg[j]:seg[j + 1]].sort()
    s = rng.uniform(1e-3, 3.0, size=n)
    out = {}
    for backend in ("segmented", "pallas"):
        start = np.empty(n)
        fin = np.empty(n)
        core_lindley.solve_segments(seg, t, s, start, fin, backend=backend)
        out[backend] = (start.tobytes(), fin.tobytes())
    assert out["segmented"] == out["pallas"]


def test_lindley_x64_scoped_to_the_call():
    """ops.lindley returns exact float64 without flipping the global x64
    default for the rest of the process."""
    t = np.array([[0.0, 0.5, 1.0]])
    s = np.array([[1.0, 1.0, 1.0]])
    got = np.asarray(ops.lindley(t, s))
    assert got.dtype == np.float64
    np.testing.assert_array_equal(got, np.array([[0.0, 1.0, 2.0]]))
    assert jnp.asarray(1.5).dtype == jnp.float32


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 128, 4, 32, 2, 16, 32), (1, 256, 2, 16, 1, 8, 64),
    (2, 64, 4, 16, 4, 16, 64)])
@pytest.mark.slow
def test_ssd_kernel(b, s, h, p, g, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.4
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.4)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    y, hf = ops.ssd(x, dt, A, Bm, Cm, chunk=chunk)
    yr, hfr = ref.ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfr),
                               rtol=1e-3, atol=1e-3)
