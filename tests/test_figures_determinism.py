"""Every registered figure must be deterministic: same seed, same rows.

Generalizes the old fig23-only CI determinism check to the whole
registry.  Each figure runs twice on the smoke fast path and the emitted
rows must serialize byte-identically — ``*/wall`` timing rows are the
only sanctioned nondeterminism and are excluded before comparison.  A
final subprocess test replays the full ``benchmarks.run --smoke --json``
sweep in two fresh interpreters, so hash randomization or import-order
effects can't hide behind in-process state.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from benchmarks import figures as figures_mod  # noqa: E402
from benchmarks.figures import ALL_FIGURES  # noqa: E402


def _rows_json(fig):
    """Run one figure on the smoke path and serialize its rows."""
    old_smoke, old_seed = figures_mod.SMOKE, figures_mod.SEED
    figures_mod.SMOKE, figures_mod.SEED = True, 0
    try:
        rows = fig()
    finally:
        figures_mod.SMOKE, figures_mod.SEED = old_smoke, old_seed
    return json.dumps([[name, float(val), str(der)]
                       for name, val, der in rows])


@pytest.mark.slow
@pytest.mark.parametrize("fig", ALL_FIGURES, ids=lambda f: f.__name__)
def test_figure_is_deterministic_under_smoke(fig):
    assert _rows_json(fig) == _rows_json(fig), (
        f"{fig.__name__} emitted different rows for the same seed")


@pytest.mark.slow
def test_full_smoke_sweep_is_deterministic_across_interpreters():
    def sweep():
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (os.path.join(REPO, "src"), REPO,
                        env.get("PYTHONPATH", "")) if p)
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--only", "fig",
             "--smoke", "--json"],
            cwd=REPO, env=env, capture_output=True, text=True, check=True)
        d = json.loads(out.stdout)
        assert d["schema"] == "figures/v2"
        return [r for r in d["rows"] if not r["name"].endswith("/wall")]

    a, b = sweep(), sweep()
    assert a == b, "smoke sweep differs between two fresh interpreters"
