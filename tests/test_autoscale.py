"""Autoscaling control loop: no-controller bit-exactness, dynamic CPU
pool, drive power cycling with wake latency, power/cost/energy accounting,
queue_stats under mid-run fleet changes, and the fig20 acceptance claim
(reactive and EWMA beat the static fleet on cost per SLA-met request under
diurnal load)."""
import math

import numpy as np
import pytest

from repro.core.arrivals import (BurstyOnOff, DiurnalProcess, PoissonProcess,
                                 TraceReplay)
from repro.core.autoscale import (AutoscaleAction, AutoscalePolicy,
                                  EWMAPolicy, ReactivePolicy, StaticPolicy,
                                  evaluate_policy, fleet_cost_usd,
                                  fleet_energy_j)
from repro.core.engine import ClusterEngine
from repro.core.function import standard_pipeline
from repro.core.latency import LatencyModel
from repro.core.scheduler import ClusterSim

PIPES = [standard_pipeline("asset_damage"),
         standard_pipeline("content_moderation", accelerate=False)]
ACCEL = [standard_pipeline("asset_damage")]


class _Recorder(AutoscalePolicy):
    """Delegate to an inner policy, recording every snapshot it saw."""

    def __init__(self, inner):
        self.inner = inner
        self.epoch_s = inner.epoch_s
        self.snaps = []

    def reset(self):
        self.snaps = []
        self.inner.reset()

    def observe(self, snap):
        self.snaps.append(snap)
        return self.inner.observe(snap)


class _Fixed(AutoscalePolicy):
    """Request the same action every epoch (no clamping of its own)."""

    def __init__(self, n_cpu, n_dscs_on, epoch_s=1.0):
        self.action = AutoscaleAction(n_cpu, n_dscs_on)
        self.epoch_s = epoch_s

    def observe(self, snap):
        return self.action


# --------------------------------------------------------------------------
# the golden-trace property: a controller must be able to ride along
# without perturbing the simulation it merely observes
# --------------------------------------------------------------------------

def test_full_fleet_static_policy_is_bit_identical_to_no_controller():
    """Epoch hooks + the full-fleet static action change no scheduling
    decision, so the RequestResult stream must be bit-identical to a run
    without any controller (the golden-trace gates stay meaningful)."""
    kw = dict(n_dscs=4, n_cpu=8, hedge_budget_s=0.05, seed=13)
    arr = PoissonProcess(rate=80.0)
    plain = ClusterEngine(**kw).run(PIPES, arrivals=arr, duration_s=8)
    eng = ClusterEngine(**kw)
    scaled = eng.run_soa(PIPES, arrivals=arr, duration_s=8,
                         controller=StaticPolicy(8, 4, epoch_s=0.5))
    assert scaled.to_results() == plain
    assert eng.power_stats()["epochs"] > 0


def test_observer_only_policy_sees_consistent_telemetry():
    rec = _Recorder(StaticPolicy(8, 4, epoch_s=1.0))
    eng = ClusterEngine(n_dscs=4, n_cpu=8, hedge_budget_s=0.05, seed=0)
    trace = eng.run_soa(PIPES, arrivals=PoissonProcess(rate=60.0),
                        duration_s=6, controller=rec)
    assert rec.snaps, "epochs must fire"
    assert [s.epoch for s in rec.snaps] == list(range(1, len(rec.snaps) + 1))
    assert all(s.time == pytest.approx(s.epoch * 1.0) for s in rec.snaps)
    # per-epoch arrival deltas sum to at most the total stream (the tail
    # after the last boundary is never reported) and every count is sane
    assert sum(s.arrivals for s in rec.snaps) <= trace.n
    for s in rec.snaps:
        assert 0 <= s.cpu_busy <= s.n_cpu_active <= s.n_cpu_total == 8
        assert 0 <= s.dscs_busy <= s.n_dscs_on <= s.n_dscs_total == 4
        assert s.dscs_queue >= 0 and s.cpu_queue >= 0


# --------------------------------------------------------------------------
# dynamic CPU pool
# --------------------------------------------------------------------------

def test_cpu_scale_down_powers_off_and_reduces_powered_seconds():
    eng = ClusterEngine(n_dscs=0, n_cpu=8, seed=0)
    eng.run_soa(PIPES, arrivals=PoissonProcess(rate=20.0), duration_s=10,
                controller=_Fixed(2, 0))
    ps = eng.power_stats()
    full = ps["horizon"] * 8
    assert 0.0 < ps["cpu"]["powered_s"] < 0.5 * full
    assert ps["cpu"]["busy_s"] <= ps["cpu"]["powered_s"] + 1e-9


def test_cpu_pool_never_drops_below_one_and_every_request_completes():
    """A policy demanding zero CPUs is clamped; the fleet still serves."""
    eng = ClusterEngine(n_dscs=0, n_cpu=4, seed=0)
    trace = eng.run_soa(PIPES, arrivals=PoissonProcess(rate=30.0),
                        duration_s=6, controller=_Fixed(0, 0))
    assert trace.n > 0
    assert np.all(np.isfinite(trace.finish))
    assert np.all(trace.winner == 1)


def test_deactivated_node_drains_run_to_completion():
    """Shrinking the pool must not drop queued or running work: every
    arrival still gets exactly one result, in arrival order."""
    eng = ClusterEngine(n_dscs=2, n_cpu=8, hedge_budget_s=0.05, seed=7)
    trace = eng.run_soa(PIPES, arrivals=PoissonProcess(rate=120.0),
                        duration_s=8, controller=_Fixed(1, 1))
    assert trace.n > 0
    assert np.all(np.isfinite(trace.finish))
    assert np.all(trace.finish >= trace.arrival)


def test_mid_run_fleet_change_queue_stats_hand_computed():
    """queue_stats under a mid-run fleet-size change: two simultaneous
    arrivals after node 1 was deactivated must share node 0 (one queues),
    and the depth integral/horizon bookkeeping must hold exactly."""
    eng = ClusterEngine(n_dscs=0, n_cpu=2, seed=0)
    res = eng.run_soa(
        [standard_pipeline("asset_damage")],
        times=np.array([0.0, 0.0, 2.0, 2.0]),
        controller=_Fixed(1, 0)).to_results()
    assert len(res) == 4
    r = sorted(res, key=lambda x: (x.arrival, x.start))
    # t=0: rid0 -> node0, rid1 -> node1 (both idle).  Epoch t=1 drops to
    # one active node.  t=2: rid2 starts on node0, rid3 queues behind it.
    assert r[2].queue_wait == 0.0
    assert r[3].start == pytest.approx(r[2].finish)
    q = eng.queue_stats()["cpu"]
    horizon = max(x.finish for x in res)
    assert q["max_depth"] == 1.0
    want_mean = (r[3].start - r[3].arrival) / (2.0 * horizon)
    assert q["mean_depth"] == pytest.approx(want_mean, abs=1e-12)
    # node 1 drained by the epoch, so it powered off at t=1.0 exactly
    ps = eng.power_stats()
    assert ps["cpu"]["powered_s"] == pytest.approx(horizon + 1.0)


def test_reactivated_node_takes_new_work():
    """Scale 4 -> 1 -> 4: after re-activation the spread of simultaneous
    arrivals across nodes is restored (no queueing), proving reactivated
    nodes rejoin the least-loaded pick."""
    class UpDown(AutoscalePolicy):
        epoch_s = 1.0

        def observe(self, snap):
            return AutoscaleAction(1 if snap.epoch < 2 else 4, 0)

    eng = ClusterEngine(n_dscs=0, n_cpu=4, seed=0)
    res = eng.run_soa([standard_pipeline("asset_damage")],
                      times=np.array([0.5, 3.0, 3.0, 3.0, 3.0]),
                      controller=UpDown()).to_results()
    late = [r for r in res if r.arrival == 3.0]
    assert len(late) == 4
    assert all(r.queue_wait == 0.0 for r in late)


# --------------------------------------------------------------------------
# drive power cycling + wake latency
# --------------------------------------------------------------------------

def test_powered_off_drive_pays_wake_latency_on_arrival():
    wake = 0.3
    eng = ClusterEngine(n_dscs=2, n_cpu=2, seed=0, dscs_wake_s=wake)
    res = eng.run_soa(ACCEL, times=np.array([2.0]),
                      controller=_Fixed(1, 0)).to_results()
    r = res[0]
    assert r.winner == "dscs"
    # drives idle from t=0 were powered off at the first epoch; the t=2
    # arrival wakes its placement drive and waits out the full penalty
    assert r.start == pytest.approx(2.0 + wake)
    ps = eng.power_stats()
    assert ps["wake_events"] == 1


def test_wake_latency_absent_when_drive_stays_on():
    eng = ClusterEngine(n_dscs=2, n_cpu=2, seed=0, dscs_wake_s=0.3)
    res = eng.run_soa(ACCEL, times=np.array([2.0]),
                      controller=_Fixed(1, 2)).to_results()
    assert res[0].winner == "dscs"
    assert res[0].queue_wait == 0.0
    assert eng.power_stats()["wake_events"] == 0


def test_hedging_races_the_waking_drive():
    """With a hedge budget shorter than the wake penalty, the CPU copy
    must win the race for a request landing on a sleeping drive."""
    eng = ClusterEngine(n_dscs=2, n_cpu=2, seed=0, dscs_wake_s=1.0,
                        hedge_budget_s=0.05)
    res = eng.run_soa(ACCEL, times=np.array([2.0]),
                      controller=_Fixed(2, 0)).to_results()
    r = res[0]
    assert r.hedged and r.winner == "cpu"
    assert r.finish - r.arrival < 1.0     # did not wait out the wake


def test_proactive_power_up_prewarms_drives():
    """A policy that powers drives back on ahead of load: an arrival after
    the wake completes pays no penalty."""
    class PreWarm(AutoscalePolicy):
        epoch_s = 1.0

        def observe(self, snap):
            # off at epoch 1, wake (proactively) at epoch 2
            return AutoscaleAction(1, 0 if snap.epoch < 2 else 2)

    eng = ClusterEngine(n_dscs=2, n_cpu=2, seed=0, dscs_wake_s=0.3)
    res = eng.run_soa(ACCEL, times=np.array([4.0]),
                      controller=PreWarm()).to_results()
    assert res[0].winner == "dscs"
    assert res[0].queue_wait == 0.0       # wake finished at 2.3 < 4.0
    assert eng.power_stats()["wake_events"] == 2


def test_powered_down_fleet_consumes_less_energy():
    lm = LatencyModel()
    arr = PoissonProcess(rate=10.0)
    kw = dict(arrivals=arr, duration_s=10, n_dscs=4, n_cpu=8, sla_s=0.6,
              seed=0, latency_model=lm)
    full = evaluate_policy(StaticPolicy(8, 4), PIPES, **kw)
    lean = evaluate_policy(StaticPolicy(1, 1), PIPES, **kw)
    assert lean.energy_j < full.energy_j
    assert lean.cost_usd < full.cost_usd
    assert lean.mean_cpu_active < full.mean_cpu_active


# --------------------------------------------------------------------------
# report accounting
# --------------------------------------------------------------------------

def test_static_full_fleet_power_accounting_closed_form():
    eng = ClusterEngine(n_dscs=2, n_cpu=4, seed=0)
    eng.run_soa(PIPES, arrivals=PoissonProcess(rate=30.0), duration_s=5,
                controller=StaticPolicy(4, 2))
    ps = eng.power_stats()
    assert ps["cpu"]["powered_s"] == pytest.approx(ps["horizon"] * 4)
    assert ps["dscs"]["powered_s"] == pytest.approx(ps["horizon"] * 2)
    energy = fleet_energy_j(ps)
    cost = fleet_cost_usd(ps, energy["total"])
    assert energy["total"] == pytest.approx(energy["cpu"] + energy["dscs"])
    assert cost["total"] == pytest.approx(
        cost["cpu_capex"] + cost["dscs_capex"] + cost["electricity"])
    assert energy["total"] > 0 and cost["total"] > 0


def test_evaluate_policy_is_deterministic():
    lm = LatencyModel()
    kw = dict(arrivals=DiurnalProcess(rate=60.0, period_s=20.0),
              duration_s=20, n_dscs=4, n_cpu=12, sla_s=0.6,
              hedge_budget_s=0.08, seed=3, latency_model=lm)
    a = evaluate_policy(ReactivePolicy(), PIPES, **kw)
    b = evaluate_policy(ReactivePolicy(), PIPES, **kw)
    assert a == b
    # a reused policy object is reset between runs
    pol = EWMAPolicy.for_pipelines(lm, PIPES)
    assert (evaluate_policy(pol, PIPES, **kw)
            == evaluate_policy(pol, PIPES, **kw))


def test_run_autoscaled_facade_matches_direct_evaluation():
    lm = LatencyModel()
    sim = ClusterSim(n_dscs=4, n_cpu=12, hedge_budget_s=0.08, seed=3,
                     latency_model=lm)
    arr = DiurnalProcess(rate=60.0, period_s=20.0)
    rep = sim.run_autoscaled(PIPES, policy=ReactivePolicy(), arrivals=arr,
                             duration_s=20)
    want = evaluate_policy(ReactivePolicy(), PIPES, arrivals=arr,
                           duration_s=20, n_dscs=4, n_cpu=12, sla_s=0.6,
                           hedge_budget_s=0.08, seed=3, latency_model=lm)
    assert rep == want
    assert rep.n_requests > 0 and rep.epochs > 0


# --------------------------------------------------------------------------
# the fig20 acceptance claim, at tier-1 scale
# --------------------------------------------------------------------------

def test_adaptive_policies_beat_static_on_cost_per_sla_met_request():
    """Under the diurnal process, reactive and EWMA must deliver a lower
    cost per SLA-met request than the peak-provisioned static fleet while
    keeping SLA attainment within a whisker of it (fig20's criterion)."""
    lm = LatencyModel()
    kw = dict(arrivals=DiurnalProcess(rate=120.0, amplitude=0.6,
                                      period_s=30.0),
              duration_s=60, n_dscs=8, n_cpu=24, sla_s=0.6,
              hedge_budget_s=0.08, seed=0, latency_model=lm)
    static = evaluate_policy(StaticPolicy(24, 8), PIPES, **kw)
    reactive = evaluate_policy(ReactivePolicy(), PIPES, **kw)
    ewma = evaluate_policy(EWMAPolicy.for_pipelines(lm, PIPES), PIPES, **kw)
    assert static.sla_frac > 0.95
    for adaptive in (reactive, ewma):
        assert adaptive.cost_per_sla_req_usd < static.cost_per_sla_req_usd
        assert adaptive.sla_frac > static.sla_frac - 0.05
        assert adaptive.energy_per_req_j < static.energy_per_req_j
        # the saving comes from actually shrinking the powered fleet
        assert adaptive.mean_cpu_active < static.mean_cpu_active


def test_ewma_policy_tracks_rate_and_static_never_moves():
    rec_s = _Recorder(StaticPolicy(12, 4))
    rec_e = _Recorder(EWMAPolicy.for_pipelines(LatencyModel(), PIPES))
    arr = DiurnalProcess(rate=80.0, amplitude=0.8, period_s=20.0)
    for rec in (rec_s, rec_e):
        ClusterEngine(n_dscs=4, n_cpu=12, seed=0).run_soa(
            PIPES, arrivals=arr, duration_s=40, controller=rec)
    assert len({s.n_cpu_active for s in rec_s.snaps}) == 1
    # the EWMA fleet breathes with the profile
    sizes = {s.n_cpu_active for s in rec_e.snaps}
    assert len(sizes) > 2
    assert min(sizes) < 12


def test_powered_seconds_clipped_to_horizon_despite_late_epochs():
    """A stale hedge timer keeps the loop alive long after the last
    completion, so epochs (and power-offs) fire past the horizon — the
    powered-seconds accounting must clip every interval to the horizon
    and never report more than horizon * fleet."""
    eng = ClusterEngine(n_dscs=2, n_cpu=4, seed=0, hedge_budget_s=5.0)
    res = eng.run_soa(ACCEL, times=np.array([0.1]),
                      controller=_Fixed(1, 0)).to_results()
    ps = eng.power_stats()
    horizon = ps["horizon"]
    assert horizon == pytest.approx(res[0].finish)
    # epochs kept firing until the stale timer drained at t ~ 5.1,
    # well past the ~0.14 s horizon
    assert ps["epochs"] >= 5
    assert ps["cpu"]["powered_s"] == pytest.approx(horizon * 4)
    assert ps["dscs"]["powered_s"] <= horizon * 2 + 1e-12


def test_snapshot_does_not_count_waking_drives_as_busy():
    """A drive mid-wake holds no copy in service; FleetSnapshot.dscs_busy
    must exclude it (it still counts as powered via n_dscs_on)."""
    rec = _Recorder(_Fixed(1, 0))
    eng = ClusterEngine(n_dscs=2, n_cpu=2, seed=0, dscs_wake_s=2.0)
    eng.run_soa(ACCEL, times=np.array([1.5]), controller=rec)
    mid_wake = [s for s in rec.snaps if 1.5 < s.time < 3.5]
    assert mid_wake, "an epoch must fire during the 2 s wake"
    for s in mid_wake:
        assert s.n_dscs_on == 1         # powered (waking) ...
        assert s.dscs_busy == 0         # ... but serving nothing yet


def test_two_tenant_queue_and_power_stats_under_mid_run_autoscale():
    """The PR-3 hand-computed depth-area test, extended to two tenants:
    tenant A lands two simultaneous requests at t=0 (one per idle node),
    an epoch at t=1 shrinks the pool to one node, then tenant B lands two
    requests at t=2 that must share node 0 (one queues).  Per-tenant
    queue depths and the fleet power accounting must finalize at the
    common horizon exactly."""
    from repro.core.tenancy import TenantSpec
    tenants = [
        TenantSpec("a", (standard_pipeline("asset_damage"),),
                   TraceReplay(trace=(0.0, 0.0))),
        TenantSpec("b", (standard_pipeline("asset_damage"),),
                   TraceReplay(trace=(2.0, 2.0))),
    ]
    eng = ClusterEngine(n_dscs=0, n_cpu=2, seed=0)
    trace = eng.run_soa(tenants=tenants, duration_s=10.0,
                        controller=_Fixed(1, 0))
    res = trace.to_results()
    assert len(res) == 4
    r = sorted(res, key=lambda x: (x.arrival, x.start))
    a0, a1, b0, b1 = r
    assert [a0.tenant, a1.tenant, b0.tenant, b1.tenant] == [0, 0, 1, 1]
    # tenant A spread over both idle nodes: no queueing at all
    assert a0.queue_wait == 0.0 and a1.queue_wait == 0.0
    # node 1 drained A's request before the t=1 epoch, so tenant B's two
    # requests share the single surviving node: b1 queues behind b0
    assert b0.queue_wait == 0.0
    assert b1.start == pytest.approx(b0.finish)
    horizon = max(x.finish for x in res)
    st = eng.tenant_stats()
    assert st["horizon"] == pytest.approx(horizon)
    # per-tenant depth integrals over the COMMON horizon: A never queued,
    # B accumulated exactly b1's wait
    assert st["queue"]["cpu"]["max_depth"] == [0.0, 1.0]
    assert st["queue"]["cpu"]["mean_depth"][0] == 0.0
    want_b = (b1.start - b1.arrival) / horizon
    assert st["queue"]["cpu"]["mean_depth"][1] == pytest.approx(want_b,
                                                                abs=1e-12)
    # per-tenant busy seconds are each tenant's own service sums
    assert st["busy_cpu_s"][0] == pytest.approx(a0.service + a1.service)
    assert st["busy_cpu_s"][1] == pytest.approx(b0.service + b1.service)
    # fleet queue_stats sees the same single queued copy, and the power
    # accounting matches the PR-3 closed form (node 1 off at t=1 exactly)
    q = eng.queue_stats()["cpu"]
    assert q["max_depth"] == 1.0
    assert q["mean_depth"] == pytest.approx(
        (b1.start - b1.arrival) / (2.0 * horizon), abs=1e-12)
    ps = eng.power_stats()
    assert ps["cpu"]["powered_s"] == pytest.approx(horizon + 1.0)


def test_worst_tenant_policy_scales_on_per_tenant_backlog():
    """A quiet tenant sharing the fleet with a bursting one: the
    aggregate-queue ReactivePolicy and the WorstTenantPolicy see the same
    snapshots, but the worst-tenant rule provisions for max(tenant_queue)
    * n_tenants, so it must grow the pool at least as far, and the
    snapshots must actually carry the per-tenant views."""
    from repro.core.autoscale import WorstTenantPolicy
    from repro.core.tenancy import TenantSpec
    pipes = (standard_pipeline("asset_damage", accelerate=False),)
    tenants = [
        TenantSpec("quiet", pipes, PoissonProcess(rate=2.0)),
        TenantSpec("bursty", pipes,
                   BurstyOnOff(rate=60.0, burst_factor=6.0, mean_on_s=2.0,
                               mean_off_s=6.0)),
    ]
    peaks = {}
    for name, pol in (("reactive", ReactivePolicy()),
                      ("worst", WorstTenantPolicy())):
        rec = _Recorder(pol)
        ClusterEngine(n_dscs=0, n_cpu=16, seed=0).run_soa(
            tenants=tenants, duration_s=20.0, controller=rec)
        assert rec.snaps
        for s in rec.snaps:
            assert len(s.tenant_queue) == 2
            assert len(s.tenant_arrivals) == 2
            assert all(v >= 0 for v in s.tenant_queue)
        assert (sum(sum(s.tenant_arrivals) for s in rec.snaps)
                <= sum(s.arrivals for s in rec.snaps))
        peaks[name] = max(s.n_cpu_active for s in rec.snaps)
    assert peaks["worst"] >= peaks["reactive"] > 1


def test_worst_tenant_policy_degrades_to_reactive_single_tenant():
    """On classic (single-tenant) runs the snapshot carries no per-tenant
    views and the policy must act exactly like ReactivePolicy."""
    from repro.core.autoscale import WorstTenantPolicy
    kw = dict(arrivals=DiurnalProcess(rate=60.0, period_s=20.0),
              duration_s=20, n_dscs=4, n_cpu=12, sla_s=0.6,
              hedge_budget_s=0.08, seed=3, latency_model=LatencyModel())
    a = evaluate_policy(ReactivePolicy(), PIPES, **kw)
    b = evaluate_policy(WorstTenantPolicy(), PIPES, **kw)
    assert a.cost_usd == b.cost_usd
    assert a.p99_s == b.p99_s


def test_policy_validation():
    class Bad(AutoscalePolicy):
        epoch_s = 0.0

        def observe(self, snap):
            return None

    with pytest.raises(ValueError):
        ClusterEngine(n_dscs=1, n_cpu=1, seed=0).run_soa(
            ACCEL, times=np.array([1.0]), controller=Bad())
    with pytest.raises(NotImplementedError):
        AutoscalePolicy().observe(None)


def test_none_action_leaves_fleet_untouched():
    class Watch(AutoscalePolicy):
        epoch_s = 1.0

        def observe(self, snap):
            return None

    kw = dict(n_dscs=2, n_cpu=4, seed=5)
    arr = PoissonProcess(rate=40.0)
    plain = ClusterEngine(**kw).run(PIPES, arrivals=arr, duration_s=5)
    watched = ClusterEngine(**kw).run_soa(
        PIPES, arrivals=arr, duration_s=5, controller=Watch()).to_results()
    assert watched == plain


def test_evaluate_policy_all_abandoned_is_nan_safe():
    """A timeout shorter than any service abandons every request: the
    percentiles must report inf (not NaN or a crash), SLA attainment and
    cost must stay well-defined."""
    rep = evaluate_policy(StaticPolicy(4, 4), ACCEL,
                          arrivals=PoissonProcess(rate=50.0),
                          duration_s=3.0, n_dscs=4, n_cpu=4, sla_s=0.6,
                          seed=11, timeout_s=1e-6)
    assert rep.n_requests > 0
    assert rep.sla_met == 0
    assert rep.sla_frac == 0.0
    assert rep.p50_s == math.inf and rep.p99_s == math.inf
    assert rep.cost_per_sla_req_usd == math.inf
    assert rep.energy_per_req_j >= 0.0
    assert not any(isinstance(v, float) and math.isnan(v)
                   for v in vars(rep).values())
