"""Overload control & metastable-failure resilience (ISSUE 10):
admission control, load shedding, backpressure, brownout, and the fig24
goodput-retention gate.

PYTHONPATH=src python -m pytest -q tests/test_overload.py
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.arrivals import PoissonProcess
from repro.core.autoscale import StaticPolicy
from repro.core.faults import ExponentialBackoff, FaultPlan
from repro.core.function import standard_pipeline
from repro.core.overload import (AdmitAll, Backpressure, Brownout,
                                 OverloadControl, QueueThreshold, ShedPolicy,
                                 ThrottledArrivals, TokenBucket,
                                 merge_overload_stats)
from repro.core.scheduler import ClusterSim
from repro.core.tenancy import TenantSpec, WeightedTimeSlice

PIPES = [standard_pipeline("asset_damage")]


def _sim(overload=None, **kw):
    kw.setdefault("n_dscs", 3)
    kw.setdefault("n_cpu", 3)
    kw.setdefault("seed", 7)
    return ClusterSim(overload=overload, **kw)


def _run(sim, *, rate=120.0, dur=6.0, timeout_s=None):
    return sim.engine.run_soa(PIPES, arrivals=PoissonProcess(rate=rate),
                              duration_s=dur, timeout_s=timeout_s)


def _conserved(tr, sim):
    """arrivals == completed + abandoned + rejected + shed, exactly."""
    fs = sim.fault_stats()
    completed = int(np.count_nonzero(tr.completed))
    dead = int(np.count_nonzero(tr.winner == -1))
    assert completed + dead == tr.n
    assert (fs["abandoned"] + fs["deadline_abandoned"] + fs["rejected"]
            + fs["shed"]) == dead
    return fs


# ---------------------------------------------------------------------------
# policy construction & validation
# ---------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0).validate()
    with pytest.raises(ValueError):
        TokenBucket(burst=0.5).validate()
    with pytest.raises(ValueError):
        QueueThreshold(max_queue_per_server=None).validate()  # no criterion
    with pytest.raises(ValueError):
        QueueThreshold(max_utilization=1.5).validate()
    with pytest.raises(ValueError):
        ShedPolicy(max_queue=3, drop="youngest").validate()
    with pytest.raises(ValueError):
        ShedPolicy(codel_target_s=0.05, codel_interval_s=0.0).validate()
    with pytest.raises(ValueError):
        Backpressure(target_depth=0.0).validate()
    with pytest.raises(ValueError):
        Brownout(on_depth=2.0, off_depth=2.0).validate()   # needs hysteresis
    with pytest.raises(ValueError):
        OverloadControl(epoch_s=0.0).validate()
    OverloadControl(admission=TokenBucket(), shed=ShedPolicy(max_queue=4),
                    backpressure=Backpressure(),
                    brownout=Brownout()).validate()


def test_enabled_predicate():
    assert not OverloadControl().enabled
    assert not OverloadControl(admission=AdmitAll()).enabled
    assert not OverloadControl(shed=ShedPolicy()).enabled  # no criteria set
    assert OverloadControl(admission=TokenBucket()).enabled
    assert OverloadControl(shed=ShedPolicy(max_queue=2)).enabled
    assert OverloadControl(backpressure=Backpressure()).enabled
    assert OverloadControl(brownout=Brownout()).enabled


def test_throttled_arrivals_validation():
    with pytest.raises(ValueError):
        ThrottledArrivals(timeline=((1.0, 0.5),))          # no inner process
    with pytest.raises(ValueError):
        ThrottledArrivals(inner=PoissonProcess(rate=10.0),
                          timeline=((1.0, 1.2),))          # factor > 1
    with pytest.raises(ValueError):
        ThrottledArrivals(inner=PoissonProcess(rate=10.0),
                          timeline=((2.0, 0.5), (1.0, 0.8)))   # unsorted


# ---------------------------------------------------------------------------
# continuity: a disabled layer is bit-exact with the classic engine
# ---------------------------------------------------------------------------

def test_disabled_layer_bit_exact():
    base = _run(_sim(None))
    noop = _run(_sim(OverloadControl(admission=AdmitAll())))
    assert np.array_equal(base.finish, noop.finish, equal_nan=True)
    assert np.array_equal(base.winner, noop.winner)
    assert _sim(OverloadControl()).overload_stats() is None


def test_disabled_layer_stats_are_none():
    sim = _sim(None)
    _run(sim)
    assert sim.overload_stats() is None


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_token_bucket_meters_admissions():
    ov = OverloadControl(admission=TokenBucket(rate=20.0, burst=4.0))
    sim = _sim(ov)
    tr = _run(sim, rate=100.0, dur=6.0)
    st = sim.overload_stats()
    fs = _conserved(tr, sim)
    assert st["rejected_by"]["admission"] == st["rejected"] > 0
    assert st["admitted"] + st["rejected"] == tr.n
    # admitted ~ rate * dur + burst, never more
    assert st["admitted"] <= 20.0 * 6.0 + 4.0 + 1
    assert fs["rejected"] == st["rejected"]
    # rejected requests are dead in the trace
    assert int(np.count_nonzero(tr.winner == -1)) >= st["rejected"]


def test_queue_threshold_rejects_only_under_load():
    ov = OverloadControl(
        admission=QueueThreshold(max_queue_per_server=2.0))
    calm = _sim(ov)
    _run(calm, rate=5.0)
    assert calm.overload_stats()["rejected"] == 0
    hot = _sim(ov)
    tr = _run(hot, rate=400.0)
    st = hot.overload_stats()
    assert st["rejected"] > 0
    _conserved(tr, hot)


def test_per_class_counters_partition_totals():
    mixed = [standard_pipeline("asset_damage"),
             standard_pipeline("asset_damage", accelerate=False)]
    ov = OverloadControl(admission=TokenBucket(rate=30.0, burst=2.0,
                                               per_class=True))
    sim = _sim(ov)
    sim.engine.run_soa(mixed, arrivals=PoissonProcess(rate=150.0),
                       duration_s=5.0)
    st = sim.overload_stats()
    for key in ("admitted", "rejected", "shed"):
        assert (st["per_class"]["accel"][key]
                + st["per_class"]["plain"][key]) == st[key]
    assert st["per_class"]["accel"]["rejected"] > 0
    assert st["per_class"]["plain"]["rejected"] > 0


# ---------------------------------------------------------------------------
# load shedding
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("drop", ["oldest", "incoming"])
def test_bounded_queue_sheds(drop):
    ov = OverloadControl(shed=ShedPolicy(max_queue=2, drop=drop))
    sim = _sim(ov)
    tr = _run(sim, rate=300.0, dur=4.0)
    st = sim.overload_stats()
    assert st["shed_by"]["bounded"] == st["shed"] > 0
    assert st["rejected"] == 0          # shedding, not admission
    _conserved(tr, sim)


def test_hopeless_shedding_requires_deadline():
    ov = OverloadControl(shed=ShedPolicy(max_queue=None, hopeless=True))
    sim = _sim(ov)
    tr = _run(sim, rate=300.0, dur=4.0, timeout_s=0.08)
    st = sim.overload_stats()
    assert st["shed_by"]["hopeless"] == st["shed"] > 0
    fs = _conserved(tr, sim)
    # a hopeless-shed copy would have missed its deadline anyway: shedding
    # must not reduce completions below the unprotected run
    naked = _sim(None)
    ntr = _run(naked, rate=300.0, dur=4.0, timeout_s=0.08)
    assert (int(np.count_nonzero(tr.completed))
            >= int(np.count_nonzero(ntr.completed)))
    assert fs["deadline_abandoned"] + fs["shed"] > 0


def test_codel_sojourn_shedding():
    ov = OverloadControl(shed=ShedPolicy(codel_target_s=0.02,
                                         codel_interval_s=0.05))
    sim = _sim(ov)
    tr = _run(sim, rate=300.0, dur=4.0)
    st = sim.overload_stats()
    assert st["shed_by"]["codel"] == st["shed"] > 0
    _conserved(tr, sim)


# ---------------------------------------------------------------------------
# backpressure & brownout
# ---------------------------------------------------------------------------

def test_backpressure_throttles_and_records_timeline():
    ov = OverloadControl(backpressure=Backpressure(target_depth=1.0,
                                                   min_factor=0.1))
    sim = _sim(ov)
    tr = _run(sim, rate=300.0, dur=6.0)
    st = sim.overload_stats()
    assert st["rejected_by"]["pushback"] == st["rejected"] > 0
    assert st["epochs"] > 0
    tl = st["pushback"]["timeline"]
    assert tl and min(f for _, f in tl) < 1.0
    assert all(0.1 <= f <= 1.0 for _, f in tl)
    _conserved(tr, sim)


def test_brownout_suspends_hedging():
    ov = OverloadControl(brownout=Brownout(on_depth=0.5, off_depth=0.1,
                                           min_epochs=1))
    hot = _sim(ov, hedge_budget_s=0.02)
    _run(hot, rate=300.0, dur=6.0)
    st = hot.overload_stats()
    assert st["brownout"]["entered"] >= 1
    assert st["hedges_suppressed"] > 0
    assert st["brownout"]["active_epochs"] >= 1
    for lo, hi in st["brownout"]["intervals"]:
        assert hi > lo >= 0.0
    # without hedging there is nothing to suppress
    cold = _sim(ov)
    _run(cold, rate=300.0, dur=6.0)
    assert cold.overload_stats()["hedges_suppressed"] == 0


def test_throttled_arrivals_thin_open_loop_stream():
    inner = PoissonProcess(rate=200.0)
    full = inner.times(10.0, np.random.default_rng(0))
    # client honors a 0.5 pushback factor from t=2s on
    th = ThrottledArrivals(inner=inner, timeline=((2.0, 0.5),))
    thin = th.times(10.0, np.random.default_rng(0))
    before = np.count_nonzero(thin < 2.0)
    after = np.count_nonzero(thin >= 2.0)
    n_before = np.count_nonzero(full < 2.0)
    n_after = np.count_nonzero(full >= 2.0)
    assert before == n_before                   # untouched before pushback
    assert abs(after - 0.5 * n_after) <= 2      # deterministic accumulator
    assert th.with_rate(50.0).inner.rate == 50.0


# ---------------------------------------------------------------------------
# retry integration & composition limits
# ---------------------------------------------------------------------------

def test_retries_consult_admission_state():
    fp = FaultPlan(drive_mtbf_s=2.0, drive_mttr_s=1.0,
                   retry=ExponentialBackoff(base_s=0.005, max_attempts=6),
                   retry_budget=None, detect_timeout_s=0.05)
    ov = OverloadControl(admission=TokenBucket(rate=30.0, burst=2.0))
    sim = _sim(ov, faults=fp)
    tr = _run(sim, rate=150.0, dur=8.0, timeout_s=0.5)
    st = sim.overload_stats()
    assert "retries_denied" in st and st["retries_denied"] >= 0
    _conserved(tr, sim)


def test_overload_rejects_non_fcfs_scheduler():
    ov = OverloadControl(admission=TokenBucket(rate=50.0))
    sim = _sim(ov)
    tenants = [TenantSpec(name="a", pipelines=PIPES,
                          arrivals=PoissonProcess(rate=20.0)),
               TenantSpec(name="b", pipelines=PIPES,
                          arrivals=PoissonProcess(rate=20.0))]
    with pytest.raises(NotImplementedError):
        sim.engine.run_soa(tenants=tenants, duration_s=2.0,
                           scheduler=WeightedTimeSlice())


def test_per_tenant_books_under_fcfs():
    ov = OverloadControl(admission=TokenBucket(rate=25.0, burst=2.0))
    sim = _sim(ov)
    tenants = [TenantSpec(name="calm", pipelines=PIPES,
                          arrivals=PoissonProcess(rate=10.0), weight=1.0),
               TenantSpec(name="greedy", pipelines=PIPES,
                          arrivals=PoissonProcess(rate=120.0), weight=1.0)]
    sim.engine.run_soa(tenants=tenants, duration_s=6.0)
    st = sim.overload_stats()
    pt = st["per_tenant"]
    assert pt is not None and pt["names"] == ["calm", "greedy"]
    assert sum(pt["admitted"]) == st["admitted"]
    assert sum(pt["rejected"]) == st["rejected"]
    # the greedy tenant exhausts its own bucket, not the calm tenant's
    assert pt["rejected"][1] > pt["rejected"][0]


# ---------------------------------------------------------------------------
# telemetry schema & snapshot signals
# ---------------------------------------------------------------------------

def test_overload_stats_schema():
    ov = OverloadControl(admission=TokenBucket(rate=30.0),
                         shed=ShedPolicy(max_queue=3, hopeless=True),
                         backpressure=Backpressure(target_depth=2.0),
                         brownout=Brownout(on_depth=2.5, off_depth=0.5))
    sim = _sim(ov, hedge_budget_s=0.05)
    _run(sim, rate=200.0, dur=5.0, timeout_s=0.4)
    st = sim.overload_stats()
    for key in ("enabled", "admitted", "rejected", "shed",
                "copies_cancelled", "rejected_by", "shed_by", "per_class",
                "per_tenant", "retries_denied", "hedges_suppressed",
                "brownout", "pushback", "epochs", "goodput"):
        assert key in st, key
    assert st["enabled"] is True
    assert set(st["rejected_by"]) == {"pushback", "admission"}
    assert set(st["shed_by"]) == {"bounded", "hopeless", "codel"}
    assert st["goodput"]["offered"] == st["admitted"] + st["rejected"]


def test_fleet_snapshot_carries_rejection_and_pushback():
    snaps = []

    class Spy(StaticPolicy):
        def observe(self, snap):
            snaps.append(snap)
            return super().observe(snap)

    ov = OverloadControl(backpressure=Backpressure(target_depth=0.5))
    sim = _sim(ov)
    sim.engine.run_soa(PIPES, arrivals=PoissonProcess(rate=300.0),
                       duration_s=5.0,
                       controller=Spy(n_cpu=3, n_dscs_on=3, epoch_s=1.0))
    assert snaps
    assert sum(s.rejected for s in snaps) > 0
    assert any(s.pushback < 1.0 for s in snaps)
    assert all(s.shed >= 0 for s in snaps)


# ---------------------------------------------------------------------------
# sharded runs
# ---------------------------------------------------------------------------

def test_sharded_overload_merges_books():
    ov = OverloadControl(admission=TokenBucket(rate=40.0, burst=8.0),
                         shed=ShedPolicy(max_queue=4),
                         backpressure=Backpressure(target_depth=2.0))
    sim = ClusterSim(n_dscs=6, n_cpu=6, seed=5, overload=ov)
    tr = sim.run_sharded(PIPES, arrivals=PoissonProcess(rate=150.0),
                         duration_s=6.0, n_shards=2, timeout_s=0.5)
    st = sim.overload_stats()
    assert st is not None and st["rejected"] > 0
    fs = _conserved(tr, sim)
    assert fs["rejected"] == st["rejected"]
    assert fs["shed"] == st["shed"]
    # shard-tagged pushback timeline: (shard, t, factor) triples
    assert all(len(ev) == 3 for ev in st["pushback"]["timeline"])


def test_merge_overload_stats_identity():
    assert merge_overload_stats([None, None]) is None
    ov = OverloadControl(admission=TokenBucket(rate=30.0, burst=4.0))
    sim = _sim(ov)
    _run(sim, rate=150.0, dur=4.0)
    solo = sim.overload_stats()
    merged = merge_overload_stats([solo, None])
    for key in ("admitted", "rejected", "shed", "copies_cancelled",
                "retries_denied", "hedges_suppressed", "epochs"):
        assert merged[key] == solo[key]
    assert merged["goodput"] == solo["goodput"]


# ---------------------------------------------------------------------------
# fig24 gate
# ---------------------------------------------------------------------------

def test_fig24_smoke_headline_gate(monkeypatch):
    import benchmarks.figures as figures_mod
    monkeypatch.setattr(figures_mod, "SMOKE", True)
    rows = figures_mod.fig24_overload()
    by_name = {n: v for n, v, _ in rows}
    assert by_name["fig24/headline/goodput_retention"] >= 2.0
    # naive goodput collapses past the knee; protected degrades gracefully
    assert (by_name["fig24/load_1.5x/naive/goodput_frac"]
            < by_name["fig24/load_1x/naive/goodput_frac"] / 2)
    assert (by_name["fig24/load_1.5x/protected/goodput_frac"]
            > by_name["fig24/load_1x/protected/goodput_frac"] / 2)
    assert by_name["fig24/load_1.5x/protected/hedges_suppressed"] > 0
