"""Per-architecture smoke tests (reduced configs) + decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import decode as DE
from repro.models import transformer as T

ARCH_NAMES = sorted(ARCHS)


def _inputs(cfg, key, B=2, S=32):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.frontend == "audio_frames":
        kw["encoder_frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    if cfg.frontend == "vision_patches":
        kw["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_seq, cfg.d_model)) * 0.02
    return tokens, kw


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.slow
def test_smoke_forward_train_step(arch):
    """Reduced config: one forward + grad step on CPU; shapes + no NaNs."""
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    tokens, kw = _inputs(cfg, key)
    logits = T.forward(cfg, params, tokens, **kw)
    assert logits.shape == (*tokens.shape, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss, grads = jax.value_and_grad(
        lambda p: T.softmax_xent(T.forward(cfg, p, tokens, **kw), tokens)
    )(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_matches_forward(arch):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    tokens, kw = _inputs(cfg, key)
    full = T.forward(cfg, params, tokens, **kw)
    pl, _ = DE.prefill(cfg, params, tokens, **kw)
    np.testing.assert_allclose(np.asarray(pl[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.slow
def test_decode_matches_forward(arch):
    """decode_step at position S must equal forward on S+1 tokens."""
    cfg = get_arch(arch).reduced()
    if cfg.num_experts:
        # no-drop capacity: batch-prefill and single-token decode otherwise
        # drop different tokens (expected capacity behaviour, not a bug)
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    key = jax.random.PRNGKey(2)
    params = T.init_params(cfg, key)
    B, S = 2, 31
    tokens, kw = _inputs(cfg, key, B, S + 1)
    full = T.forward(cfg, params, tokens, **kw)
    _, cache = DE.prefill(cfg, params, tokens[:, :S], **kw)
    cache = _grow(cfg, cache, B, S + 1)
    dl, cache2 = DE.decode_step(cfg, params, cache, tokens[:, S:S + 1])
    assert int(cache2["pos"]) == S + 1
    np.testing.assert_allclose(np.asarray(dl[:, 0], np.float32),
                               np.asarray(full[:, S], np.float32),
                               rtol=2e-2, atol=2e-3)


def _grow(cfg, cache, B, cap):
    tmpl = DE.cache_shapes(cfg, B, cap)
    new = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tmpl)

    def copy(dst, src):
        if dst.shape == src.shape:
            return src
        return dst.at[tuple(slice(0, s) for s in src.shape)].set(src)

    new = jax.tree.map(copy, new, cache)
    new["pos"] = cache["pos"]
    return new


@pytest.mark.slow
def test_sliding_window_ring_cache_equivalence():
    """Hybrid arch: ring-buffer decode == full-cache decode for in-window
    positions."""
    cfg = dataclasses.replace(get_arch("recurrentgemma-2b").reduced(),
                              sliding_window=16)
    key = jax.random.PRNGKey(3)
    params = T.init_params(cfg, key)
    B, S = 1, 48   # S > window -> ring cache engaged
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    full = T.forward(cfg, params, tokens)
    _, cache = DE.prefill(cfg, params, tokens[:, :S])
    cache = _grow(cfg, cache, B, S + 1)
    dl, _ = DE.decode_step(cfg, params, cache, tokens[:, S:S + 1])
    np.testing.assert_allclose(np.asarray(dl[:, 0], np.float32),
                               np.asarray(full[:, S], np.float32),
                               rtol=2e-2, atol=2e-3)


def test_moe_routes_tokens_and_balances():
    cfg = get_arch("qwen3-moe-235b-a22b").reduced()
    key = jax.random.PRNGKey(4)
    params = T.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    l1 = T.forward(cfg, params, tokens)
    # different tokens must produce different expert mixtures -> diff logits
    tokens2 = (tokens + 7) % cfg.vocab_size
    l2 = T.forward(cfg, params, tokens2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-4


@pytest.mark.slow
def test_vision_models_shapes():
    from repro.models import vision
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (1, 32, 32, 3))
    r = vision.resnet50_apply(vision.resnet50_init(key, width=0.125,
                                                   classes=10), x)
    assert r.shape == (1, 10) and not bool(jnp.any(jnp.isnan(r)))
    e = vision.effnet_apply(vision.effnet_init(key, width=0.25, classes=10), x)
    assert e.shape == (1, 10)
    f = vision.fcn_apply(vision.fcn_init(key, width=0.125, classes=5), x)
    assert f.shape == (1, 32, 32, 5)
    y = vision.yolov3_apply(vision.yolov3_init(key, width=0.125), x)
    assert y.shape[0] == 1 and y.shape[-1] == 255
    v = vision.vit_apply(vision.vit_init(key, layers=2, d=64, heads=2,
                                         d_ff=128, patch=8, classes=10), x)
    assert v.shape == (1, 10)


def test_count_params_matches_init():
    cfg = get_arch("qwen3-8b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    assert n == T.count_params(cfg)
