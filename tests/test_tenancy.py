"""Multi-tenant DSA sharing: the tenant model layer, deterministic
arrival multiplexing, the pluggable drive schedulers (FCFS baseline,
weighted time-slicing, spatial lane partitioning), per-tenant telemetry,
fairness scoring, and the fig21 isolation claim at tier-1 scale."""
import numpy as np
import pytest

from repro.core.arrivals import (BurstyOnOff, MergedArrivals, PoissonProcess,
                                 TraceReplay)
from repro.core.engine import ClusterEngine
from repro.core.function import standard_pipeline
from repro.core.scheduler import ClusterSim
from repro.core.tenancy import (FCFSRunToCompletion, SpatialPartition,
                                TenantSpec, WeightedTimeSlice, assign_lanes,
                                isolation_violation_rate, jain_index,
                                tenant_reports)

ACCEL = (standard_pipeline("asset_damage"),)
PLAIN = (standard_pipeline("asset_damage", accelerate=False),)


def _noisy_pair(sla_latency=0.15):
    """A latency-sensitive tenant sharing drives with a bursty neighbor."""
    return [
        TenantSpec("latency", ACCEL, PoissonProcess(rate=15.0),
                   sla_s=sla_latency),
        TenantSpec("noisy", ACCEL,
                   BurstyOnOff(rate=40.0, burst_factor=6.0, mean_on_s=2.0,
                               mean_off_s=8.0), sla_s=1.0),
    ]


# --------------------------------------------------------------------------
# MergedArrivals: deterministic multiplexing
# --------------------------------------------------------------------------

def test_merged_arrivals_sorted_attributed_and_deterministic():
    m = MergedArrivals(processes=(PoissonProcess(rate=50.0),
                                  BurstyOnOff(rate=30.0)))
    ts, src = m.times_and_sources(20.0, np.random.default_rng(0))
    assert ts.size == src.size > 0
    assert np.all(np.diff(ts) >= 0.0)
    assert set(np.unique(src)) == {0, 1}
    ts2, src2 = m.times_and_sources(20.0, np.random.default_rng(0))
    assert np.array_equal(ts, ts2) and np.array_equal(src, src2)
    # rate is derived from the components
    assert m.rate == pytest.approx(80.0)
    # times() is the merged stream
    assert np.array_equal(m.times(20.0, np.random.default_rng(0)), ts)


def test_merged_components_are_independent():
    """Re-parameterizing one component must not perturb another's stream
    (each draws from its own indexed child generator)."""
    a = MergedArrivals(processes=(PoissonProcess(rate=20.0),
                                  PoissonProcess(rate=20.0)))
    b = MergedArrivals(processes=(PoissonProcess(rate=20.0),
                                  PoissonProcess(rate=200.0)))
    ts_a, src_a = a.times_and_sources(10.0, np.random.default_rng(3))
    ts_b, src_b = b.times_and_sources(10.0, np.random.default_rng(3))
    assert np.array_equal(ts_a[src_a == 0], ts_b[src_b == 0])
    assert not np.array_equal(ts_a[src_a == 1], ts_b[src_b == 1])


def test_merged_single_component_passes_rng_through():
    """One component = nothing to interleave: the stream is bit-identical
    to calling the component directly (golden-gate continuity)."""
    p = PoissonProcess(rate=40.0)
    m = MergedArrivals(processes=(p,))
    assert np.array_equal(m.times(8.0, np.random.default_rng(5)),
                          p.times(8.0, np.random.default_rng(5)))


def test_merged_with_rate_rescales_proportionally():
    m = MergedArrivals(processes=(PoissonProcess(rate=30.0),
                                  PoissonProcess(rate=10.0)))
    m2 = m.with_rate(80.0)
    assert m2.rate == pytest.approx(80.0)
    assert m2.processes[0].rate == pytest.approx(60.0)
    assert m2.processes[1].rate == pytest.approx(20.0)
    with pytest.raises(ValueError):
        MergedArrivals(processes=())


# --------------------------------------------------------------------------
# tenant/scheduler value objects
# --------------------------------------------------------------------------

def test_tenant_spec_validation():
    with pytest.raises(ValueError):
        TenantSpec("t", (), PoissonProcess(rate=1.0))
    with pytest.raises(ValueError):
        TenantSpec("t", ACCEL, PoissonProcess(rate=1.0), sla_s=0.0)
    with pytest.raises(ValueError):
        TenantSpec("t", ACCEL, PoissonProcess(rate=1.0), weight=-1.0)
    # list pipelines normalize to a tuple (hashable frozen spec)
    t = TenantSpec("t", list(ACCEL), PoissonProcess(rate=1.0))
    assert isinstance(t.pipelines, tuple)


def test_scheduler_validation():
    with pytest.raises(ValueError):
        WeightedTimeSlice(quantum_s=0.0)
    with pytest.raises(ValueError):
        WeightedTimeSlice(switch_s=-0.1)
    with pytest.raises(ValueError):
        SpatialPartition(lanes=-1)
    eng = ClusterEngine(n_dscs=2, n_cpu=2, seed=0)
    with pytest.raises(ValueError):        # scheduler needs tenants
        eng.run_soa(list(ACCEL), times=np.array([0.1]),
                    scheduler=WeightedTimeSlice())
    with pytest.raises(TypeError):         # unknown scheduler object
        eng.run_soa(tenants=_noisy_pair(), duration_s=1.0,
                    scheduler=object())
    with pytest.raises(ValueError):        # tenants exclude times/arrivals
        eng.run_soa(tenants=_noisy_pair(), duration_s=1.0,
                    times=np.array([0.1]))
    with pytest.raises(ValueError):        # tenants exclude pipelines
        eng.run_soa(list(ACCEL), tenants=_noisy_pair(), duration_s=1.0)


def test_assign_lanes_proportional_with_floor():
    assert assign_lanes([1.0, 1.0], 2) == [1, 1]
    assert assign_lanes([3.0, 1.0], 4) == [3, 1]
    assert assign_lanes([1.0, 1.0, 1.0], 4) == [2, 1, 1]   # tie -> low index
    assert assign_lanes([0.1, 10.0], 8) == [1, 7]          # floor holds
    with pytest.raises(ValueError):
        assign_lanes([1.0, 1.0, 1.0], 2)


def test_jain_index():
    assert jain_index([1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_index([1.0, 0.0, 0.0]) == pytest.approx(1.0 / 3.0)
    assert jain_index([]) == 1.0
    assert jain_index([0.0, 0.0]) == 1.0
    assert isolation_violation_rate(0.4, 0.9) == pytest.approx(0.5)
    assert isolation_violation_rate(0.95, 0.9) == 0.0


# --------------------------------------------------------------------------
# the golden-gate property: one default tenant + FCFS == classic engine
# --------------------------------------------------------------------------

def test_single_default_tenant_fcfs_is_bit_identical_to_classic_run():
    """The tenant layer must thread identity through the engine without
    perturbing it: one default tenant under the FCFS scheduler consumes
    the same arrival/pick/service streams and emits the bit-identical
    RequestResult stream (so the golden-trace gates extend over it)."""
    pipes = [standard_pipeline(n)
             for n in ("asset_damage", "content_moderation")]
    kw = dict(n_dscs=4, n_cpu=8, hedge_budget_s=0.05, seed=13)
    arr = PoissonProcess(rate=80.0)
    classic = ClusterEngine(**kw).run(pipes, arrivals=arr, duration_s=8)
    eng = ClusterEngine(**kw)
    trace = eng.run_soa(
        tenants=[TenantSpec("default", tuple(pipes), arr)], duration_s=8,
        scheduler=FCFSRunToCompletion())
    assert trace.to_results() == classic
    assert np.all(trace.tenant == 0)
    st = eng.tenant_stats()
    assert st["arrivals"] == [len(classic)]
    assert st["completions"] == [len(classic)]


def test_classic_run_reports_zero_tenant_column():
    eng = ClusterEngine(n_dscs=2, n_cpu=2, seed=0)
    trace = eng.run_soa(list(ACCEL), arrivals=PoissonProcess(rate=20.0),
                        duration_s=3)
    assert np.all(trace.tenant == 0)
    assert all(r.tenant == 0 for r in trace.to_results())
    assert eng.tenant_stats() is None


# --------------------------------------------------------------------------
# multi-tenant conservation + attribution (every scheduler)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("sched", [
    None, WeightedTimeSlice(quantum_s=0.01, switch_s=0.001),
    SpatialPartition()])
def test_every_tenant_arrival_completes_and_is_attributed(sched):
    tenants = _noisy_pair()
    eng = ClusterEngine(n_dscs=4, n_cpu=4, seed=1)
    trace = eng.run_soa(tenants=tenants, duration_s=20.0, scheduler=sched)
    assert trace.n > 0
    assert np.all(np.isfinite(trace.finish))
    assert np.all(trace.finish >= trace.arrival - 1e-9)
    st = eng.tenant_stats()
    for k in range(2):
        n_k = int(np.count_nonzero(trace.tenant == k))
        assert n_k > 0
        assert st["arrivals"][k] == n_k
        assert st["completions"][k] == n_k
        assert st["busy_dscs_s"][k] > 0.0
    # the merged stream matches each tenant's own independent stream
    assert st["scheduler"] == (sched.name if sched else "fcfs")
    rep = tenant_reports(trace, tenants, st)
    assert [r.name for r in rep] == ["latency", "noisy"]
    assert sum(r.arrivals for r in rep) == trace.n


def test_run_determinism_per_scheduler():
    tenants = _noisy_pair()
    for sched in (WeightedTimeSlice(), SpatialPartition()):
        a = ClusterEngine(n_dscs=3, n_cpu=3, seed=5).run_soa(
            tenants=tenants, duration_s=10.0, scheduler=sched)
        b = ClusterEngine(n_dscs=3, n_cpu=3, seed=5).run_soa(
            tenants=tenants, duration_s=10.0, scheduler=sched)
        assert np.array_equal(a.finish, b.finish)
        assert np.array_equal(a.tenant, b.tenant)
        assert np.array_equal(a.service, b.service)


# --------------------------------------------------------------------------
# weighted time-slicing semantics (hand-computed on one drive)
# --------------------------------------------------------------------------

def test_timeslice_preempts_and_charges_switch_cost():
    """Two tenants, one request each at t=0 on one drive, quantum shorter
    than either service: the DSA must alternate between the copies,
    paying the switch cost on every tenant change, and both copies'
    wall-clock spans must exceed their pure service (interleaved
    segments)."""
    q, sw = 0.01, 0.005
    tenants = [
        TenantSpec("a", ACCEL, TraceReplay(trace=(0.0,))),
        TenantSpec("b", ACCEL, TraceReplay(trace=(0.0,))),
    ]
    eng = ClusterEngine(n_dscs=1, n_cpu=1, seed=0)
    trace = eng.run_soa(tenants=tenants, duration_s=1.0,
                        scheduler=WeightedTimeSlice(quantum_s=q,
                                                    switch_s=sw))
    res = sorted(trace.to_results(), key=lambda r: r.tenant)
    a, b = res
    assert a.winner == b.winner == "dscs"
    # a (lower source index on the t=0 tie) starts first with no switch
    # cost (first context load is free); b's first segment starts after
    # a's first quantum plus one context switch
    assert a.start == 0.0
    assert b.start == pytest.approx(q + sw)
    # both services need several quanta, so both spans are interleaved
    assert a.service > q and b.service > q
    assert a.finish - a.start > a.service - 1e-12
    assert b.finish - b.start > b.service - 1e-12
    st = eng.tenant_stats()
    ps = eng.power_stats()
    # the drive's busy seconds are exactly the two services plus the
    # context-switch overhead, and overhead = switches * switch_s
    n_switch = round(st["switch_overhead_s"] / sw)
    assert st["switch_overhead_s"] == pytest.approx(n_switch * sw)
    assert n_switch >= 3
    assert ps["dscs"]["busy_s"] == pytest.approx(
        a.service + b.service + st["switch_overhead_s"])
    # per-tenant busy drive-seconds include each tenant's own service
    assert sum(st["busy_dscs_s"]) == pytest.approx(ps["dscs"]["busy_s"])


def test_timeslice_weights_set_drain_order():
    """Equal backlogs (30 requests each at t=0) on one drive with weights
    2:1 — the heavier tenant drains its queue first, at roughly 3/4 of
    the lighter tenant's makespan (it holds 2/3 of the DSA while both
    are backlogged, then the lighter one finishes alone)."""
    burst = tuple([0.0] * 30)
    tenants = [
        TenantSpec("heavy", ACCEL, TraceReplay(trace=burst), weight=2.0),
        TenantSpec("light", ACCEL, TraceReplay(trace=burst), weight=1.0),
    ]
    eng = ClusterEngine(n_dscs=1, n_cpu=1, seed=2)
    trace = eng.run_soa(tenants=tenants, duration_s=1.0,
                        scheduler=WeightedTimeSlice(quantum_s=0.005,
                                                    switch_s=0.0))
    fin = trace.finish
    tid = np.asarray(trace.tenant)
    last_heavy = float(fin[tid == 0].max())
    last_light = float(fin[tid == 1].max())
    assert last_heavy < last_light
    assert last_heavy / last_light == pytest.approx(0.75, abs=0.08)


def test_timeslice_isolates_latency_tenant_from_noisy_neighbor():
    """The fig21 acceptance claim at tier-1 scale: time-slicing must cut
    the latency tenant's p99 by >= 2x versus FCFS under a bursty noisy
    neighbor (it is orders of magnitude in practice)."""
    tenants = _noisy_pair()
    p99 = {}
    for name, sched in (("fcfs", None),
                        ("ts", WeightedTimeSlice(quantum_s=0.01,
                                                 switch_s=0.001))):
        eng = ClusterEngine(n_dscs=3, n_cpu=2, seed=0)
        trace = eng.run_soa(tenants=tenants, duration_s=20.0,
                            scheduler=sched)
        lat = trace.latency[np.asarray(trace.tenant) == 0]
        p99[name] = float(np.percentile(lat, 99))
    assert p99["fcfs"] >= 2.0 * p99["ts"]


# --------------------------------------------------------------------------
# spatial partitioning semantics
# --------------------------------------------------------------------------

def test_spatial_partition_serves_tenants_concurrently_with_inflated_service():
    """Two equal-weight tenants on a one-drive fleet: each holds one of
    two lanes, so simultaneous arrivals start immediately in parallel,
    each at exactly 2x its solo service time (half the PEs)."""
    tenants = [
        TenantSpec("a", ACCEL, TraceReplay(trace=(0.0,))),
        TenantSpec("b", ACCEL, TraceReplay(trace=(0.0,))),
    ]
    eng = ClusterEngine(n_dscs=1, n_cpu=1, seed=3)
    trace = eng.run_soa(tenants=tenants, duration_s=1.0,
                        scheduler=SpatialPartition())
    res = sorted(trace.to_results(), key=lambda r: r.tenant)
    a, b = res
    assert a.start == 0.0 and b.start == 0.0          # no queueing at all
    assert a.finish == pytest.approx(a.service)
    # solo run (same seed): the first service draw is shared, unscaled
    solo = ClusterEngine(n_dscs=1, n_cpu=1, seed=3).run_soa(
        tenants=[TenantSpec("a", ACCEL, TraceReplay(trace=(0.0,)))],
        duration_s=1.0)
    assert a.service == solo.to_results()[0].service * 2.0


def test_spatial_partition_respects_lane_weights():
    """lanes=4 with weights 3:1 -> 3 lanes vs 1 lane: service inflation
    4/3 vs 4/1 (the weighted tenant runs 3x faster per request)."""
    tenants = [
        TenantSpec("big", ACCEL, TraceReplay(trace=(0.0,)), weight=3.0),
        TenantSpec("small", ACCEL, TraceReplay(trace=(0.0,)), weight=1.0),
    ]
    eng = ClusterEngine(n_dscs=1, n_cpu=1, seed=3)
    trace = eng.run_soa(tenants=tenants, duration_s=1.0,
                        scheduler=SpatialPartition(lanes=4))
    res = sorted(trace.to_results(), key=lambda r: r.tenant)
    solo = ClusterEngine(n_dscs=1, n_cpu=1, seed=3).run_soa(
        tenants=[TenantSpec("big", ACCEL, TraceReplay(trace=(0.0,)))],
        duration_s=1.0).to_results()[0]
    assert res[0].service == pytest.approx(solo.service * 4.0 / 3.0)


def test_spatial_fleet_queue_area_counts_other_lanes_backlog():
    """An idle lane starting a request must first settle the drive's
    pending depth area — the *other* tenant's lane can hold queued copies
    at that moment (regression: sp_start_new used to reset the accounting
    clock and drop that area).  Fleet mean depth must equal the sum of
    the per-tenant means, and match the hand-computed integral."""
    tenants = [
        TenantSpec("backlog", ACCEL, TraceReplay(trace=(0.0, 0.0, 0.0))),
        TenantSpec("late", ACCEL, TraceReplay(trace=(0.05,))),
    ]
    eng = ClusterEngine(n_dscs=1, n_cpu=1, seed=3)
    trace = eng.run_soa(tenants=tenants, duration_s=1.0,
                        scheduler=SpatialPartition())
    res = trace.to_results()
    tid = np.asarray(trace.tenant)
    a = sorted((r for r in res if r.tenant == 0), key=lambda r: r.start)
    # tenant 0: one runs from t=0, two queue behind it on its lane; the
    # depth integral is 2*(second start) + 1*(third start - second start)
    assert len(a) == 3
    want_area = 2.0 * a[1].start + (a[2].start - a[1].start)
    horizon = max(r.finish for r in res)
    st = eng.tenant_stats()
    assert st["queue"]["dscs"]["mean_depth"][0] == pytest.approx(
        want_area / horizon, abs=1e-12)
    assert st["queue"]["dscs"]["mean_depth"][1] == 0.0
    q = eng.queue_stats()["dscs"]
    assert q["mean_depth"] == pytest.approx(sum(
        st["queue"]["dscs"]["mean_depth"]), abs=1e-12)
    # tenant 1 arrived mid-backlog and started instantly on its own lane
    late = res[int(np.flatnonzero(tid == 1)[0])]
    assert late.start == pytest.approx(0.05)


def test_spatial_isolation_beats_fcfs_for_latency_tenant():
    tenants = _noisy_pair()
    p99 = {}
    for name, sched in (("fcfs", None), ("sp", SpatialPartition())):
        eng = ClusterEngine(n_dscs=3, n_cpu=2, seed=0)
        trace = eng.run_soa(tenants=tenants, duration_s=20.0,
                            scheduler=sched)
        lat = trace.latency[np.asarray(trace.tenant) == 0]
        p99[name] = float(np.percentile(lat, 99))
    assert p99["fcfs"] >= 2.0 * p99["sp"]


# --------------------------------------------------------------------------
# hedging composes with the shared-DSA schedulers
# --------------------------------------------------------------------------

@pytest.mark.parametrize("sched", [
    WeightedTimeSlice(quantum_s=0.01, switch_s=0.001), SpatialPartition()])
def test_hedging_composes_with_shared_dsa_schedulers(sched):
    tenants = _noisy_pair()
    eng = ClusterEngine(n_dscs=2, n_cpu=6, hedge_budget_s=0.05, seed=0)
    trace = eng.run_soa(tenants=tenants, duration_s=15.0, scheduler=sched)
    assert np.all(np.isfinite(trace.finish))
    assert int(trace.hedged.sum()) > 0
    # some hedges were won by the CPU path (the drives are saturated)
    assert eng.telemetry.get("hedge_won_cpu") > 0
    # reclaimed time is never negative, and only time-slicing can reclaim
    # without the preempt flag (dropped mid-slice losers)
    assert eng.telemetry.get("reclaimed_dscs_s") >= 0.0


def test_facade_run_tenants_returns_trace_and_reports():
    sim = ClusterSim(n_dscs=3, n_cpu=3, seed=0)
    trace, reps = sim.run_tenants(_noisy_pair(), duration_s=10.0,
                                  scheduler=WeightedTimeSlice())
    assert trace.n == sum(r.arrivals for r in reps) > 0
    assert [r.name for r in reps] == ["latency", "noisy"]
    assert all(0.0 <= r.sla_frac <= 1.0 for r in reps)
    assert sim.tenant_stats()["scheduler"] == "timeslice"
    # mean queue depth is bounded by max depth for every tenant
    for r in reps:
        assert r.max_queue_depth >= 0.0
        assert r.mean_queue_depth >= 0.0
