"""Int8 gradient compression with error feedback: convergence preserved."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import compression as C


def test_wire_bytes_4x():
    params = {"a": jnp.zeros((128, 128)), "b": jnp.zeros((64,))}
    full, comp = C.wire_bytes(params)
    assert full / comp > 3.5


def test_error_feedback_unbiased_over_time():
    """Sum of compressed grads ~= sum of true grads (error feedback)."""
    key = jax.random.PRNGKey(0)
    g_true = jax.random.normal(key, (256,))
    err = jnp.zeros((256,))
    acc = jnp.zeros((256,))
    for i in range(50):
        deq, err = C.compress_grads(g_true, err)
        acc = acc + deq
    # accumulated compressed signal converges to accumulated true signal
    rel = float(jnp.linalg.norm(acc - 50 * g_true) / jnp.linalg.norm(50 * g_true))
    assert rel < 0.01, rel


def test_training_converges_with_compression():
    """Toy regression: int8+EF reaches ~the same loss as exact grads."""
    key = jax.random.PRNGKey(1)
    X = jax.random.normal(key, (128, 16))
    w_true = jax.random.normal(jax.random.PRNGKey(2), (16,))
    y = X @ w_true

    def loss(w):
        return jnp.mean((X @ w - y) ** 2)

    def run(compressed: bool):
        w = jnp.zeros((16,))
        err = jnp.zeros((16,))
        for _ in range(200):
            g = jax.grad(loss)(w)
            if compressed:
                g, err = C.compress_grads(g, err)
            w = w - 0.05 * g
        return float(loss(w))

    exact, comp = run(False), run(True)
    assert comp < max(2 * exact, 1e-4), (exact, comp)
