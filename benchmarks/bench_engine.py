"""Engine perf harness: simulated-requests/sec across fleet size × arrival
shape × request count, tracked across PRs in ``BENCH_engine.json``.

Each configuration runs in a fresh subprocess (clean peak-RSS accounting,
no cache bleed between configs).  The optimized engine is measured through
its native ``ClusterEngine.run_soa`` array path; the pre-PR2 baseline is
the frozen object-based engine in :mod:`repro.core.engine_ref`, measured
through its ``run`` object path (its only path).  Both simulate the exact
same seed-for-seed workload (the golden-trace tests prove the result
streams are bit-identical), so wall-clock is the only thing that differs.

    python -m benchmarks.bench_engine              # full sweep -> BENCH_engine.json
    python -m benchmarks.bench_engine --no-baseline  # skip slow reference runs
    python -m benchmarks.bench_engine --smoke      # CI gate: 10^4-request config,
                                                   # fail on >3x regression vs the
                                                   # committed BENCH_engine.json
    python -m benchmarks.bench_engine --smoke-shards  # CI gate: sharded engine at
                                                   # n_shards in {1,2,4}, aggregate
                                                   # equality + relative speedup
    python -m benchmarks.bench_engine --one '<json>'  # internal: one config/engine

``BENCH_engine.json`` schema (``schema: bench_engine/v2``)::

    {
      "schema": "bench_engine/v2",
      "host": {"python": ..., "numpy": ...},
      "configs": [
        {
          "name": "poisson-1m-f256",
          "arrival": "poisson" | "bursty" | "diurnal",
          "n_requests_target": 1000000,   # rate*duration; realized n varies
          "n_dscs": 256, "n_cpu": 256,
          "utilization": 0.95,            # offered DSCS load fraction
          "hedge_budget_s": 0.08,
          "engine":   {"requests": ..., "events": ..., "wall_s": ...,
                       "req_per_s": ..., "peak_rss_kb": ...},
          "sharded":  {"n_shards": 8, "processes": 1, "requests": ...,
                       "events": ..., "wall_s": ...,   # best of 3 in-process
                       "cold_wall_s": ...,             # first rep (cold caches)
                       "req_per_s": ..., "peak_rss_kb": ...,
                       "speedup_vs_single": sharded/engine req_per_s},
          "baseline": {... engine fields, "events" omitted ...} | null,
          "speedup": engine.req_per_s / baseline.req_per_s | null
        }, ...
      ]
    }

The ``v2`` shards axis measures ``ClusterEngine.run_sharded`` on the
partitioned fast path: best of 3 reps in one subprocess (the placement
table is memoized process-wide, matching how a resident service would
run; ``cold_wall_s`` records the first cold rep for transparency).

Both smoke gates are RELATIVE: they rerun the comparison on the current
host and check the measured ratio against the committed one, failing on a
>3x drop — host speed cancels out of the ratio, so only a real regression
in the optimized hot path (not a slow CI runner) trips the gate.
``--smoke-shards`` additionally asserts shard-count independence at smoke
scale: the partitioned path must produce byte-identical finish times for
``n_shards`` 2 and 4.
"""
from __future__ import annotations

import argparse
import json
import resource
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BENCH_PATH = REPO / "BENCH_engine.json"
SCHEMA = "bench_engine/v2"
BENCH_SHARDS = 8                        # the headline shards-axis point

# All configs run at utilization 0.95 — the SLA-knee operating point the
# Fig. 12 throughput-under-SLA methodology probes, where queueing (and the
# pre-PR2 engine's O(depth) list operations) actually matters.
SMOKE = {"name": "poisson-10k-smoke", "arrival": "poisson",
         "n_requests_target": 10_000, "n_dscs": 64, "n_cpu": 64,
         "utilization": 0.95, "hedge_budget_s": 0.08, "baseline": True}

# fleet size x arrival shape x request count (the 1e6 Poisson rows carry
# the acceptance-criterion baseline comparison; the 1024-node fleet is the
# headline — it is where the pre-PR2 O(n_cpu) least-loaded scan and O(depth)
# queue ops diverge hardest from the new O(log n) indexed-heap/deque path)
CONFIGS = [SMOKE] + [
    {"name": f"{shape}-{label}-f{fleet}", "arrival": shape,
     "n_requests_target": n_req, "n_dscs": fleet, "n_cpu": fleet,
     "utilization": 0.95, "hedge_budget_s": 0.08,
     "baseline": shape == "poisson"}
    for fleet in (64, 256, 1024)
    for shape in ("poisson", "bursty")
    for n_req, label in ((100_000, "100k"), (1_000_000, "1m"))
]


def _run_one(cfg: dict, which: str) -> dict:
    """Run one config on one engine in-process; returns the measurement."""
    from repro.core.arrivals import make_arrivals
    from repro.core.latency import LatencyModel
    from repro.core.function import standard_pipeline
    from repro.core.platforms import PLATFORMS

    pipes = [standard_pipeline(n)
             for n in ("asset_damage", "content_moderation")]
    lm = LatencyModel()
    svc = sum(lm.e2e(PLATFORMS["DSCS-Serverless"], p.workload, q=0.5)
              for p in pipes) / len(pipes)
    rate = cfg["utilization"] * cfg["n_dscs"] / svc
    duration = cfg["n_requests_target"] / rate
    arrivals = make_arrivals(cfg["arrival"], rate)

    if which == "engine":
        from repro.core.engine import ClusterEngine
        eng = ClusterEngine(n_dscs=cfg["n_dscs"], n_cpu=cfg["n_cpu"],
                            hedge_budget_s=cfg["hedge_budget_s"], seed=0)
        t0 = time.perf_counter()
        trace = eng.run_soa(pipes, arrivals=arrivals, duration_s=duration)
        wall = time.perf_counter() - t0
        n, events = trace.n, trace.events
    elif which == "sharded":
        from repro.core.engine import ClusterEngine
        n_shards = int(cfg.get("n_shards", BENCH_SHARDS))
        processes = int(cfg.get("processes", 1))
        walls = []
        for _ in range(3):              # best of 3; rep 1 is the cold one
            eng = ClusterEngine(n_dscs=cfg["n_dscs"], n_cpu=cfg["n_cpu"],
                                hedge_budget_s=cfg["hedge_budget_s"], seed=0)
            t0 = time.perf_counter()
            trace = eng.run_sharded(pipes, arrivals=arrivals,
                                    duration_s=duration, n_shards=n_shards,
                                    processes=processes)
            walls.append(time.perf_counter() - t0)
        wall = min(walls)
        n, events = trace.n, trace.events
        out = {"n_shards": n_shards, "processes": processes,
               "requests": n, "events": events, "wall_s": round(wall, 3),
               "cold_wall_s": round(walls[0], 3),
               "req_per_s": round(n / wall, 1),
               "peak_rss_kb":
                   resource.getrusage(resource.RUSAGE_SELF).ru_maxrss}
        return out
    else:
        from repro.core.engine_ref import ReferenceClusterEngine
        eng = ReferenceClusterEngine(n_dscs=cfg["n_dscs"], n_cpu=cfg["n_cpu"],
                                     hedge_budget_s=cfg["hedge_budget_s"],
                                     seed=0)
        t0 = time.perf_counter()
        res = eng.run(pipes, arrivals=arrivals, duration_s=duration)
        wall = time.perf_counter() - t0
        n, events = len(res), None
    out = {"requests": n, "wall_s": round(wall, 3),
           "req_per_s": round(n / wall, 1),
           "peak_rss_kb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss}
    if events is not None:
        out["events"] = events
    return out


def _spawn(cfg: dict, which: str) -> dict:
    """Run one (config, engine) measurement in a fresh subprocess."""
    payload = json.dumps({"cfg": cfg, "which": which})
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_engine", "--one", payload],
        capture_output=True, text=True, cwd=REPO,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed for {cfg['name']}/{which}:"
                           f"\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _smoke(args) -> int:
    # The gate is RELATIVE: both engines run on this host and the measured
    # optimized-vs-reference speedup is compared against the committed
    # smoke speedup, so a slow/contended CI runner rescales both sides and
    # only a real complexity/constant-factor regression in the optimized
    # path trips the gate.  Best of 3 on the fast engine because its ~0.1s
    # run is at the mercy of GC pauses / cold CPU governors.
    res = max((_run_one(SMOKE, "engine") for _ in range(3)),
              key=lambda r: r["req_per_s"])
    base = _run_one(SMOKE, "baseline")
    speedup = res["req_per_s"] / base["req_per_s"]
    print(f"smoke: {res['requests']} requests, engine "
          f"{res['req_per_s']:,.0f} req/s (best of 3), reference "
          f"{base['req_per_s']:,.0f} req/s -> speedup {speedup:.1f}x")
    if not BENCH_PATH.exists():
        print(f"no committed {BENCH_PATH.name}; smoke run is informational")
        return 0
    committed = json.loads(BENCH_PATH.read_text())
    ref = next((c for c in committed.get("configs", [])
                if c["name"] == SMOKE["name"]), None)
    if ref is None or not ref.get("speedup"):
        print("committed BENCH_engine.json has no smoke speedup; skipping gate")
        return 0
    floor = ref["speedup"] / 3.0
    if speedup < floor:
        print(f"FAIL: measured speedup {speedup:.1f}x is >3x below the "
              f"committed {ref['speedup']}x")
        return 1
    print(f"OK: within 3x of the committed {ref['speedup']}x speedup")
    return 0


def _smoke_shards(args) -> int:
    """Shard-matrix smoke: n_shards in {1, 2, 4} on the smoke config.

    Gates the committed shards-axis speedup at reduced scale (relative,
    like ``--smoke``): the measured sharded-vs-single throughput ratio
    must stay within 3x of the committed ``speedup_vs_single``.  Also
    asserts shard-count independence — the partitioned path must emit
    byte-identical finish times for 2 and 4 shards.
    """
    from repro.core.arrivals import make_arrivals
    from repro.core.engine import ClusterEngine
    from repro.core.function import standard_pipeline
    from repro.core.latency import LatencyModel
    from repro.core.platforms import PLATFORMS

    pipes = [standard_pipeline(n)
             for n in ("asset_damage", "content_moderation")]
    lm = LatencyModel()
    svc = sum(lm.e2e(PLATFORMS["DSCS-Serverless"], p.workload, q=0.5)
              for p in pipes) / len(pipes)
    rate = SMOKE["utilization"] * SMOKE["n_dscs"] / svc
    duration = SMOKE["n_requests_target"] / rate

    rps, finishes = {}, {}
    for k in (1, 2, 4):
        best, trace = 0.0, None
        for _ in range(3):
            eng = ClusterEngine(n_dscs=SMOKE["n_dscs"],
                                n_cpu=SMOKE["n_cpu"],
                                hedge_budget_s=SMOKE["hedge_budget_s"],
                                seed=0)
            t0 = time.perf_counter()
            trace = eng.run_sharded(pipes,
                                    arrivals=make_arrivals("poisson", rate),
                                    duration_s=duration, n_shards=k,
                                    processes=1)
            best = max(best, trace.n / (time.perf_counter() - t0))
        rps[k] = best
        finishes[k] = trace.finish.tobytes()
        print(f"smoke-shards: n_shards={k} {trace.n} requests, "
              f"{best:,.0f} req/s (best of 3)")
    if finishes[2] != finishes[4]:
        print("FAIL: partitioned traces differ between 2 and 4 shards")
        return 1
    print("OK: n_shards=2 and n_shards=4 finish streams byte-identical")
    speedup = max(rps[2], rps[4]) / rps[1]
    print(f"smoke-shards: sharded-vs-single speedup {speedup:.1f}x")
    if not BENCH_PATH.exists():
        print(f"no committed {BENCH_PATH.name}; run is informational")
        return 0
    committed = json.loads(BENCH_PATH.read_text())
    ref = next((c for c in committed.get("configs", [])
                if c["name"] == SMOKE["name"]), None)
    ref_speedup = (ref or {}).get("sharded", {}) or {}
    ref_speedup = ref_speedup.get("speedup_vs_single")
    if not ref_speedup:
        print("committed BENCH_engine.json has no sharded smoke entry; "
              "skipping gate")
        return 0
    floor = ref_speedup / 3.0
    if speedup < floor:
        print(f"FAIL: measured sharded speedup {speedup:.1f}x is >3x below "
              f"the committed {ref_speedup}x")
        return 1
    print(f"OK: within 3x of the committed {ref_speedup}x sharded speedup")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="10^4-request regression gate vs committed JSON")
    ap.add_argument("--smoke-shards", action="store_true",
                    dest="smoke_shards",
                    help="shard-matrix gate: n_shards in {1,2,4} on the "
                         "smoke config, equality + relative speedup")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the slow frozen-reference baseline runs")
    ap.add_argument("--one", default="",
                    help="internal: run one {cfg, which} payload in-process")
    ap.add_argument("--out", default=str(BENCH_PATH),
                    help="output JSON path (default: repo-root BENCH file)")
    args = ap.parse_args(argv)

    if args.one:
        payload = json.loads(args.one)
        print(json.dumps(_run_one(payload["cfg"], payload["which"])))
        return 0
    if args.smoke:
        return _smoke(args)
    if args.smoke_shards:
        return _smoke_shards(args)

    import numpy as np
    out = {"schema": SCHEMA,
           "host": {"python": sys.version.split()[0],
                    "numpy": np.__version__},
           "configs": []}
    for cfg in CONFIGS:
        want_baseline = cfg.get("baseline", False) and not args.no_baseline
        row = {k: v for k, v in cfg.items() if k != "baseline"}
        print(f"[{cfg['name']}] optimized engine ...", flush=True)
        row["engine"] = _spawn(cfg, "engine")
        print(f"  {row['engine']['req_per_s']:>12,.0f} req/s   "
              f"({row['engine']['wall_s']}s, "
              f"{row['engine']['peak_rss_kb'] // 1024} MB)", flush=True)
        print(f"[{cfg['name']}] sharded engine ({BENCH_SHARDS} shards) ...",
              flush=True)
        row["sharded"] = _spawn(cfg, "sharded")
        row["sharded"]["speedup_vs_single"] = round(
            row["sharded"]["req_per_s"] / row["engine"]["req_per_s"], 2)
        print(f"  {row['sharded']['req_per_s']:>12,.0f} req/s   "
              f"(best of 3, cold {row['sharded']['cold_wall_s']}s) "
              f"{row['sharded']['speedup_vs_single']}x vs single",
              flush=True)
        if want_baseline:
            print(f"[{cfg['name']}] frozen pre-PR2 baseline ...", flush=True)
            row["baseline"] = _spawn(cfg, "baseline")
            row["speedup"] = round(row["engine"]["req_per_s"]
                                   / row["baseline"]["req_per_s"], 2)
            print(f"  {row['baseline']['req_per_s']:>12,.0f} req/s   "
                  f"speedup {row['speedup']}x", flush=True)
        else:
            row["baseline"] = None
            row["speedup"] = None
        out["configs"].append(row)

    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
