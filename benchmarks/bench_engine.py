"""Engine perf harness: simulated-requests/sec across fleet size × arrival
shape × request count, tracked across PRs in ``BENCH_engine.json``.

Each configuration runs in a fresh subprocess (clean peak-RSS accounting,
no cache bleed between configs).  The optimized engine is measured through
its native ``ClusterEngine.run_soa`` array path; the pre-PR2 baseline is
the frozen object-based engine in :mod:`repro.core.engine_ref`, measured
through its ``run`` object path (its only path).  Both simulate the exact
same seed-for-seed workload (the golden-trace tests prove the result
streams are bit-identical), so wall-clock is the only thing that differs.

    python -m benchmarks.bench_engine              # full sweep -> BENCH_engine.json
    python -m benchmarks.bench_engine --no-baseline  # skip slow reference runs
    python -m benchmarks.bench_engine --smoke      # CI gate: 10^4-request config,
                                                   # fail on >3x regression vs the
                                                   # committed BENCH_engine.json
    python -m benchmarks.bench_engine --smoke-shards  # CI gate: sharded engine at
                                                   # n_shards in {1,2,4}, aggregate
                                                   # equality + relative speedup
    python -m benchmarks.bench_engine --one '<json>'  # internal: one config/engine

``BENCH_engine.json`` schema (``schema: bench_engine/v3``)::

    {
      "schema": "bench_engine/v3",
      "host": {"python": ..., "numpy": ...},
      "configs": [
        {
          "name": "poisson-1m-f256",
          "arrival": "poisson" | "bursty" | "diurnal",
          "n_requests_target": 1000000,   # rate*duration; realized n varies
          "n_dscs": 256, "n_cpu": 256,
          "utilization": 0.95,            # offered DSCS load fraction
          "hedge_budget_s": 0.08,
          "engine":   {"backend": "classic", "requests": ..., "events": ...,
                       "wall_s": ..., "req_per_s": ..., "peak_rss_kb": ...},
          "sharded":  {"backend": "segmented", "n_shards": 8,
                       "processes": 1, "requests": ...,
                       "events": ..., "wall_s": ...,   # best of 3 in-process
                       "cold_wall_s": ...,             # first rep (cold caches)
                       "req_per_s": ..., "peak_rss_kb": ...,
                       "speedup_vs_single": sharded/engine req_per_s},
          "baseline": {"backend": "reference", ... "events" omitted} | null,
          "speedup": engine.req_per_s / baseline.req_per_s | null
        },
        # the 10^7-request config skips the (too-slow) single-engine and
        # reference runs and instead carries a backend axis: "sharded" is
        # the segmented default, "sharded_dense" the legacy padded-dense
        # solver (peak RSS recorded per backend, segmented gated <= 4 GB)
        {"name": "poisson-10m-f1024", ..., "engine": null,
         "sharded": {...}, "sharded_dense": {...},
         "backend_speedup": segmented/dense req_per_s},
        # solver-level Zipf microbench: the hot-drive skew regime where
        # the dense (n_servers, longest_queue) pad blows up — tracks the
        # skewed-workload speedup of the segmented solver
        {"name": "lindley-zipf-1m", "kind": "solver", "n_servers": 128,
         "zipf_s": 1.2, "segmented": {...}, "dense": {...},
         "speedup": segmented/dense req_per_s}, ...
      ]
    }

The shards axis measures ``ClusterEngine.run_sharded`` on the
partitioned fast path: best of 3 reps in one subprocess (the placement
table is memoized process-wide, matching how a resident service would
run; ``cold_wall_s`` records the first cold rep for transparency).
Every measurement entry names the solver ``backend`` that produced it
(``classic``/``reference`` for the event-loop engines,
:data:`repro.core.lindley.BACKENDS` members for sharded/solver runs).

Both smoke gates are RELATIVE: they rerun the comparison on the current
host and check the measured ratio against the committed one, failing on a
>3x drop — host speed cancels out of the ratio, so only a real regression
in the optimized hot path (not a slow CI runner) trips the gate.
``--smoke-shards`` additionally asserts shard-count independence at smoke
scale: the partitioned path must produce byte-identical finish times for
``n_shards`` 2 and 4.
"""
from __future__ import annotations

import argparse
import json
import resource
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BENCH_PATH = REPO / "BENCH_engine.json"
SCHEMA = "bench_engine/v3"
BENCH_SHARDS = 8                        # the headline shards-axis point
RSS_CAP_10M_KB = 4 * 1024 * 1024       # 10^7-request peak-RSS gate (4 GB)

# All configs run at utilization 0.95 — the SLA-knee operating point the
# Fig. 12 throughput-under-SLA methodology probes, where queueing (and the
# pre-PR2 engine's O(depth) list operations) actually matters.
SMOKE = {"name": "poisson-10k-smoke", "arrival": "poisson",
         "n_requests_target": 10_000, "n_dscs": 64, "n_cpu": 64,
         "utilization": 0.95, "hedge_budget_s": 0.08, "baseline": True}

# fleet size x arrival shape x request count (the 1e6 Poisson rows carry
# the acceptance-criterion baseline comparison; the 1024-node fleet is the
# headline — it is where the pre-PR2 O(n_cpu) least-loaded scan and O(depth)
# queue ops diverge hardest from the new O(log n) indexed-heap/deque path)
CONFIGS = [SMOKE] + [
    {"name": f"{shape}-{label}-f{fleet}", "arrival": shape,
     "n_requests_target": n_req, "n_dscs": fleet, "n_cpu": fleet,
     "utilization": 0.95, "hedge_budget_s": 0.08,
     "baseline": shape == "poisson"}
    for fleet in (64, 256, 1024)
    for shape in ("poisson", "bursty")
    for n_req, label in ((100_000, "100k"), (1_000_000, "1m"))
] + [
    # 10^7 requests: sharded-only (the single event loop would take
    # minutes), both Lindley backends, peak RSS gated <= 4 GB on the
    # segmented default.  Excluded from --smoke / --smoke-shards.
    {"name": "poisson-10m-f1024", "arrival": "poisson",
     "n_requests_target": 10_000_000, "n_dscs": 1024, "n_cpu": 1024,
     "utilization": 0.95, "hedge_budget_s": 0.08, "baseline": False,
     "single_engine": False, "reps": 2,
     "backends": ["segmented", "dense"]},
    # solver-level Zipf skew: one hot server owns ~27% of 10^6 requests,
    # so the dense pad allocates (128, ~270k) float64 blocks while the
    # segmented solver stays O(n) — the skewed-workload speedup criterion
    {"name": "lindley-zipf-1m", "kind": "solver",
     "n_requests_target": 1_000_000, "n_servers": 128, "zipf_s": 1.2},
]


def _run_one(cfg: dict, which: str) -> dict:
    """Run one config on one engine in-process; returns the measurement."""
    if which == "solver":
        return _run_solver(cfg)
    from repro.core.arrivals import make_arrivals
    from repro.core.latency import LatencyModel
    from repro.core.function import standard_pipeline
    from repro.core.platforms import PLATFORMS

    pipes = [standard_pipeline(n)
             for n in ("asset_damage", "content_moderation")]
    lm = LatencyModel()
    svc = sum(lm.e2e(PLATFORMS["DSCS-Serverless"], p.workload, q=0.5)
              for p in pipes) / len(pipes)
    rate = cfg["utilization"] * cfg["n_dscs"] / svc
    duration = cfg["n_requests_target"] / rate
    arrivals = make_arrivals(cfg["arrival"], rate)

    if which == "engine":
        from repro.core.engine import ClusterEngine
        eng = ClusterEngine(n_dscs=cfg["n_dscs"], n_cpu=cfg["n_cpu"],
                            hedge_budget_s=cfg["hedge_budget_s"], seed=0)
        t0 = time.perf_counter()
        trace = eng.run_soa(pipes, arrivals=arrivals, duration_s=duration)
        wall = time.perf_counter() - t0
        n, events, backend = trace.n, trace.events, "classic"
    elif which == "sharded":
        from repro.core.engine import ClusterEngine
        n_shards = int(cfg.get("n_shards", BENCH_SHARDS))
        processes = int(cfg.get("processes", 1))
        backend = cfg.get("backend", "segmented")
        walls = []
        for _ in range(int(cfg.get("reps", 3))):   # rep 1 is the cold one
            eng = ClusterEngine(n_dscs=cfg["n_dscs"], n_cpu=cfg["n_cpu"],
                                hedge_budget_s=cfg["hedge_budget_s"], seed=0)
            t0 = time.perf_counter()
            trace = eng.run_sharded(pipes, arrivals=arrivals,
                                    duration_s=duration, n_shards=n_shards,
                                    processes=processes, backend=backend)
            walls.append(time.perf_counter() - t0)
        wall = min(walls)
        n, events = trace.n, trace.events
        out = {"backend": backend, "n_shards": n_shards,
               "processes": processes,
               "requests": n, "events": events, "wall_s": round(wall, 3),
               "cold_wall_s": round(walls[0], 3),
               "req_per_s": round(n / wall, 1),
               "peak_rss_kb":
                   resource.getrusage(resource.RUSAGE_SELF).ru_maxrss}
        return out
    else:
        from repro.core.engine_ref import ReferenceClusterEngine
        eng = ReferenceClusterEngine(n_dscs=cfg["n_dscs"], n_cpu=cfg["n_cpu"],
                                     hedge_budget_s=cfg["hedge_budget_s"],
                                     seed=0)
        t0 = time.perf_counter()
        res = eng.run(pipes, arrivals=arrivals, duration_s=duration)
        wall = time.perf_counter() - t0
        n, events, backend = len(res), None, "reference"
    out = {"backend": backend, "requests": n, "wall_s": round(wall, 3),
           "req_per_s": round(n / wall, 1),
           "peak_rss_kb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss}
    if events is not None:
        out["events"] = events
    return out


def _run_solver(cfg: dict) -> dict:
    """Zipf-skewed Lindley microbench: one solver backend, in-process.

    Draws ``n`` requests over ``n_servers`` queues with Zipf(``zipf_s``)
    popularity (the hot-drive regime: the top server owns a constant
    fraction of the whole stream), then times ``solve_segments`` + the
    vectorized depth-max.  Run per-backend in separate subprocesses so
    peak RSS is attributable."""
    import numpy as np
    from repro.core import lindley

    backend = cfg["backend"]
    n = int(cfg["n_requests_target"])
    nserv = int(cfg["n_servers"])
    rng = np.random.default_rng(0)
    ranks = np.arange(1, nserv + 1, dtype=np.float64)
    p = ranks ** -float(cfg["zipf_s"])
    p /= p.sum()
    keys = np.sort(rng.choice(nserv, size=n, p=p))
    t = np.sort(rng.uniform(0.0, n / 1e4, size=n))   # sorted per segment too
    s = rng.uniform(1e-4, 2e-3, size=n)
    seg = lindley.segment_fenceposts(keys, 0, nserv)
    start = np.empty(n)
    fin = np.empty(n)
    walls = []
    for _ in range(int(cfg.get("reps", 3))):
        t0 = time.perf_counter()
        lindley.solve_segments(seg, t, s, start, fin, backend=backend)
        lindley.queue_depth_max(seg, start, t)
        walls.append(time.perf_counter() - t0)
    wall = min(walls)
    return {"backend": backend, "requests": n, "n_servers": nserv,
            "longest_queue": int(np.diff(seg).max()),
            "wall_s": round(wall, 3), "cold_wall_s": round(walls[0], 3),
            "req_per_s": round(n / wall, 1),
            "peak_rss_kb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss}


def _spawn(cfg: dict, which: str) -> dict:
    """Run one (config, engine) measurement in a fresh subprocess."""
    payload = json.dumps({"cfg": cfg, "which": which})
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_engine", "--one", payload],
        capture_output=True, text=True, cwd=REPO,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed for {cfg['name']}/{which}:"
                           f"\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _smoke(args) -> int:
    # The gate is RELATIVE: both engines run on this host and the measured
    # optimized-vs-reference speedup is compared against the committed
    # smoke speedup, so a slow/contended CI runner rescales both sides and
    # only a real complexity/constant-factor regression in the optimized
    # path trips the gate.  Best of 3 on the fast engine because its ~0.1s
    # run is at the mercy of GC pauses / cold CPU governors.
    res = max((_run_one(SMOKE, "engine") for _ in range(3)),
              key=lambda r: r["req_per_s"])
    base = _run_one(SMOKE, "baseline")
    speedup = res["req_per_s"] / base["req_per_s"]
    print(f"smoke: {res['requests']} requests, engine "
          f"{res['req_per_s']:,.0f} req/s (best of 3), reference "
          f"{base['req_per_s']:,.0f} req/s -> speedup {speedup:.1f}x")
    if not BENCH_PATH.exists():
        print(f"no committed {BENCH_PATH.name}; smoke run is informational")
        return 0
    committed = json.loads(BENCH_PATH.read_text())
    ref = next((c for c in committed.get("configs", [])
                if c["name"] == SMOKE["name"]), None)
    if ref is None or not ref.get("speedup"):
        print("committed BENCH_engine.json has no smoke speedup; skipping gate")
        return 0
    floor = ref["speedup"] / 3.0
    if speedup < floor:
        print(f"FAIL: measured speedup {speedup:.1f}x is >3x below the "
              f"committed {ref['speedup']}x")
        return 1
    print(f"OK: within 3x of the committed {ref['speedup']}x speedup")
    return 0


def _smoke_shards(args) -> int:
    """Shard-matrix smoke: n_shards in {1, 2, 4} on the smoke config.

    Gates the committed shards-axis speedup at reduced scale (relative,
    like ``--smoke``): the measured sharded-vs-single throughput ratio
    must stay within 3x of the committed ``speedup_vs_single``.  Also
    asserts shard-count independence — the partitioned path must emit
    byte-identical finish times for 2 and 4 shards.
    """
    from repro.core.arrivals import make_arrivals
    from repro.core.engine import ClusterEngine
    from repro.core.function import standard_pipeline
    from repro.core.latency import LatencyModel
    from repro.core.platforms import PLATFORMS

    pipes = [standard_pipeline(n)
             for n in ("asset_damage", "content_moderation")]
    lm = LatencyModel()
    svc = sum(lm.e2e(PLATFORMS["DSCS-Serverless"], p.workload, q=0.5)
              for p in pipes) / len(pipes)
    rate = SMOKE["utilization"] * SMOKE["n_dscs"] / svc
    duration = SMOKE["n_requests_target"] / rate

    rps, finishes = {}, {}
    for k in (1, 2, 4):
        best, trace = 0.0, None
        for _ in range(3):
            eng = ClusterEngine(n_dscs=SMOKE["n_dscs"],
                                n_cpu=SMOKE["n_cpu"],
                                hedge_budget_s=SMOKE["hedge_budget_s"],
                                seed=0)
            t0 = time.perf_counter()
            trace = eng.run_sharded(pipes,
                                    arrivals=make_arrivals("poisson", rate),
                                    duration_s=duration, n_shards=k,
                                    processes=1)
            best = max(best, trace.n / (time.perf_counter() - t0))
        rps[k] = best
        finishes[k] = trace.finish.tobytes()
        print(f"smoke-shards: n_shards={k} {trace.n} requests, "
              f"{best:,.0f} req/s (best of 3)")
    if finishes[2] != finishes[4]:
        print("FAIL: partitioned traces differ between 2 and 4 shards")
        return 1
    print("OK: n_shards=2 and n_shards=4 finish streams byte-identical")
    speedup = max(rps[2], rps[4]) / rps[1]
    print(f"smoke-shards: sharded-vs-single speedup {speedup:.1f}x")
    if not BENCH_PATH.exists():
        print(f"no committed {BENCH_PATH.name}; run is informational")
        return 0
    committed = json.loads(BENCH_PATH.read_text())
    ref = next((c for c in committed.get("configs", [])
                if c["name"] == SMOKE["name"]), None)
    ref_speedup = (ref or {}).get("sharded", {}) or {}
    ref_speedup = ref_speedup.get("speedup_vs_single")
    if not ref_speedup:
        print("committed BENCH_engine.json has no sharded smoke entry; "
              "skipping gate")
        return 0
    floor = ref_speedup / 3.0
    if speedup < floor:
        print(f"FAIL: measured sharded speedup {speedup:.1f}x is >3x below "
              f"the committed {ref_speedup}x")
        return 1
    print(f"OK: within 3x of the committed {ref_speedup}x sharded speedup")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="10^4-request regression gate vs committed JSON")
    ap.add_argument("--smoke-shards", action="store_true",
                    dest="smoke_shards",
                    help="shard-matrix gate: n_shards in {1,2,4} on the "
                         "smoke config, equality + relative speedup")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the slow frozen-reference baseline runs")
    ap.add_argument("--one", default="",
                    help="internal: run one {cfg, which} payload in-process")
    ap.add_argument("--out", default=str(BENCH_PATH),
                    help="output JSON path (default: repo-root BENCH file)")
    args = ap.parse_args(argv)

    if args.one:
        payload = json.loads(args.one)
        print(json.dumps(_run_one(payload["cfg"], payload["which"])))
        return 0
    if args.smoke:
        return _smoke(args)
    if args.smoke_shards:
        return _smoke_shards(args)

    import numpy as np
    out = {"schema": SCHEMA,
           "host": {"python": sys.version.split()[0],
                    "numpy": np.__version__},
           "configs": []}
    fail = 0
    for cfg in CONFIGS:
        row = {k: v for k, v in cfg.items()
               if k not in ("baseline", "single_engine", "reps", "backends")}
        if cfg.get("kind") == "solver":
            for be in ("segmented", "dense"):
                print(f"[{cfg['name']}] {be} solver ...", flush=True)
                row[be] = _spawn({**cfg, "backend": be}, "solver")
                print(f"  {row[be]['req_per_s']:>12,.0f} req/s   "
                      f"({row[be]['wall_s']}s, "
                      f"{row[be]['peak_rss_kb'] // 1024} MB, longest queue "
                      f"{row[be]['longest_queue']:,})", flush=True)
            row["speedup"] = round(row["segmented"]["req_per_s"]
                                   / row["dense"]["req_per_s"], 2)
            print(f"  skewed-workload speedup {row['speedup']}x "
                  "(segmented vs dense)", flush=True)
            out["configs"].append(row)
            continue

        want_baseline = cfg.get("baseline", False) and not args.no_baseline
        if cfg.get("single_engine", True):
            print(f"[{cfg['name']}] optimized engine ...", flush=True)
            row["engine"] = _spawn(cfg, "engine")
            print(f"  {row['engine']['req_per_s']:>12,.0f} req/s   "
                  f"({row['engine']['wall_s']}s, "
                  f"{row['engine']['peak_rss_kb'] // 1024} MB)", flush=True)
        else:
            row["engine"] = None
        for i, be in enumerate(cfg.get("backends", ["segmented"])):
            key = "sharded" if i == 0 else f"sharded_{be}"
            print(f"[{cfg['name']}] sharded engine ({BENCH_SHARDS} shards, "
                  f"{be}) ...", flush=True)
            row[key] = _spawn({**cfg, "backend": be}, "sharded")
            row[key]["speedup_vs_single"] = (
                round(row[key]["req_per_s"] / row["engine"]["req_per_s"], 2)
                if row["engine"] else None)
            vs = row[key]["speedup_vs_single"]
            print(f"  {row[key]['req_per_s']:>12,.0f} req/s   "
                  f"(cold {row[key]['cold_wall_s']}s, "
                  f"{row[key]['peak_rss_kb'] // 1024} MB)"
                  + (f" {vs}x vs single" if vs is not None else ""),
                  flush=True)
        if len(cfg.get("backends", ["segmented"])) > 1:
            row["backend_speedup"] = round(
                row["sharded"]["req_per_s"]
                / row[f"sharded_{cfg['backends'][1]}"]["req_per_s"], 2)
        if cfg["n_requests_target"] >= 10_000_000:
            rss = row["sharded"]["peak_rss_kb"]
            if rss > RSS_CAP_10M_KB:
                print(f"FAIL: {cfg['name']} segmented peak RSS "
                      f"{rss // 1024} MB exceeds the "
                      f"{RSS_CAP_10M_KB // 1024} MB cap")
                fail = 1
            else:
                print(f"  RSS gate OK: {rss // 1024} MB <= "
                      f"{RSS_CAP_10M_KB // 1024} MB")
        if want_baseline:
            print(f"[{cfg['name']}] frozen pre-PR2 baseline ...", flush=True)
            row["baseline"] = _spawn(cfg, "baseline")
            row["speedup"] = round(row["engine"]["req_per_s"]
                                   / row["baseline"]["req_per_s"], 2)
            print(f"  {row['baseline']['req_per_s']:>12,.0f} req/s   "
                  f"speedup {row['speedup']}x", flush=True)
        else:
            row["baseline"] = None
            row["speedup"] = None
        out["configs"].append(row)

    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return fail


if __name__ == "__main__":
    sys.exit(main())
