"""Benchmark harness: one function per paper table/figure, plus kernel
micro-benchmarks and the roofline summary.  Prints ``name,us_per_call,
derived`` CSV (for analytic figures the middle column is the metric value),
or a ``figures/v2`` JSON envelope ``{schema, seed, smoke, rows}`` with
``--json`` — each row is ``{name, value, derived, ci95}`` where ``ci95``
is null for a single run and a ``[mean, halfwidth]`` pair when emitted by
``benchmarks.montecarlo``.

    python -m benchmarks.run                  # everything
    python -m benchmarks.run --only fig19     # one figure family
    python -m benchmarks.run --list           # enumerate figures
    python -m benchmarks.run --only fig12 --json   # machine-readable rows
    python -m benchmarks.run --only fig21 --smoke --json  # CI fast path
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _kernel_micro():
    """Pallas kernels (interpret mode on CPU): wall-time per call + checksum
    against the ref oracle."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref

    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 256), jnp.float32)
    w = jax.random.normal(key, (256, 256), jnp.float32)

    def timed(name, fn, reference):
        out = fn()                       # compile+warm
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) * 1e6
        err = float(jnp.max(jnp.abs(
            (out[0] if isinstance(out, (tuple, list)) else out).astype(jnp.float32)
            - (reference[0] if isinstance(reference, (tuple, list)) else reference)
            .astype(jnp.float32))))
        rows.append((f"kernel/{name}", us, f"max_err={err:.2e}"))

    timed("systolic_matmul_256", lambda: ops.matmul(x, w),
          ref.matmul_ref(x, w))
    q = jax.random.normal(key, (1, 4, 128, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2, 128, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2, 128, 64), jnp.float32)
    timed("flash_attention_128", lambda: ops.attention(q, k, v, bq=64, bk=64),
          ref.attention_ref(q, k, v))
    s = jax.random.normal(key, (256,))
    b = jax.random.normal(key, (256,))
    timed("vector_engine_affine", lambda: ops.affine_act(x, s, b, act="gelu"),
          ref.affine_act_ref(x, s, b, act="gelu"))
    xr = jax.random.normal(key, (2, 64, 128)) * 0.1
    la = jax.random.normal(key, (128,))
    h0 = jnp.zeros((2, 128))
    timed("rglru_scan", lambda: ops.rglru(xr, xr, xr, la, h0),
          ref.rglru_ref(xr, xr, xr, la, h0))
    xs = jax.random.normal(key, (1, 128, 2, 16)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(key, (1, 128, 2)))
    A = -jnp.exp(jax.random.normal(key, (2,)) * 0.3)
    Bm = jax.random.normal(key, (1, 128, 1, 8)) * 0.3
    timed("ssd_scan", lambda: ops.ssd(xs, dt, A, Bm, Bm, chunk=32),
          ref.ssd_ref(xs, dt, A, Bm, Bm, chunk=32))
    import numpy as np
    from jax.experimental import enable_x64
    rng = np.random.default_rng(3)
    tq = np.sort(rng.uniform(0.0, 50.0, size=(64, 128)), axis=1)
    sq = rng.uniform(1e-3, 2.0, size=(64, 128))
    with enable_x64():
        lref = ref.lindley_ref(jnp.asarray(tq), jnp.asarray(sq))
    timed("lindley_scan", lambda: ops.lindley(tq, sq), lref)
    return rows


def _roofline_summary():
    """Condense the dry-run JSONs into headline roofline rows."""
    import glob
    import json
    rows = []
    files = sorted(glob.glob("results/dryrun/*__single__train.json"))
    for f in files:
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        t = r["roofline"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        rows.append((f"roofline/{r['arch']}/{r['shape']}", bound,
                     f"dom={t['dominant']} frac={t['roofline_fraction']:.3f}"))
    return rows


def main(argv=None) -> None:
    from benchmarks import figures as figures_mod
    from benchmarks.figures import ALL_FIGURES
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="",
                    help="run only figures whose name contains this")
    ap.add_argument("--list", action="store_true", dest="list_figs",
                    help="print figure names and exit")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit a JSON array of rows instead of CSV")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink expensive simulation figures to the "
                         "CI-sized fast path (same structure and "
                         "acceptance ratios)")
    ap.add_argument("--seed", type=int, default=0,
                    help="simulation seed for every figure (montecarlo "
                         "fans one config across many seeds)")
    ap.add_argument("--backend", default="segmented",
                    choices=("segmented", "pallas", "dense"),
                    help="Lindley solver backend for sharded figure "
                         "sweeps (repro.core.lindley; all backends are "
                         "bit-identical, default unchanged)")
    args = ap.parse_args(argv)
    if args.smoke:
        figures_mod.SMOKE = True
    figures_mod.SEED = args.seed
    figures_mod.BACKEND = args.backend
    figures = [f for f in ALL_FIGURES
               if args.only.lower() in f.__name__.lower()]
    if args.list_figs:
        for fig in figures:
            print(fig.__name__)
        return

    collected = []

    def emit(name, val, derived):
        if args.as_json:
            collected.append({"name": name, "value": float(val),
                              "derived": str(derived), "ci95": None})
        else:
            print(f"{name},{val:.6g},{derived}")
            sys.stdout.flush()

    if not args.as_json:
        print("name,us_per_call,derived")
    failures = []
    for fig in figures:
        t0 = time.perf_counter()
        try:
            rows = fig()
        except Exception as exc:        # noqa: BLE001 - report, then fail run
            failures.append(fig.__name__)
            print(f"FAILED {fig.__name__}: {type(exc).__name__}: {exc}",
                  file=sys.stderr)
            continue
        dt = (time.perf_counter() - t0) * 1e6
        for name, val, derived in rows:
            emit(name, val, derived)
        emit(f"{fig.__name__}/wall", dt, "us")
    if not args.only:
        for name, us, derived in _kernel_micro():
            emit(name, us, derived)
        for name, val, derived in _roofline_summary():
            emit(name, val, derived)
    if args.as_json:
        # figures/v2 envelope: single-run rows carry ci95=null; the
        # montecarlo driver replaces them with [mean, halfwidth] pairs
        json.dump({"schema": "figures/v2", "seed": args.seed,
                   "smoke": bool(args.smoke), "rows": collected},
                  sys.stdout, indent=2)
        print()
    if failures:
        # exit non-zero so CI smoke gates never read a partial sweep as
        # a pass; the JSON above is still complete for what did run
        raise SystemExit(f"{len(failures)} figure(s) failed: "
                         + ", ".join(failures))


if __name__ == "__main__":
    main()
