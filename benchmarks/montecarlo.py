"""Many-seed Monte Carlo driver for the figure benchmarks.

Fans one figure configuration across ``--seeds`` independent simulation
seeds (one ``python -m benchmarks.run --json --seed s`` subprocess per
seed, optionally ``--jobs`` of them at once) and aggregates every
headline metric into ``mean ± 95% CI``.  Output is the same
``figures/v2`` envelope ``benchmarks.run --json`` emits, with each row's
``ci95`` field filled in as a ``[mean, halfwidth]`` pair — so anything
that can read a single-seed sweep can read a Monte Carlo sweep.

    python -m benchmarks.montecarlo --only fig19 --seeds 8
    python -m benchmarks.montecarlo --smoke --seeds 8 --json mc.json

Per-run bookkeeping rows (``*/wall`` timings) are dropped: wall time
varies with host load, not with the seed, and a CI on it would be
noise dressed up as signal.  Metrics that go non-finite on any seed
(e.g. an all-abandoned run pushing a percentile to ``inf``) keep
``value`` from the first seed and report ``ci95: null`` rather than a
meaningless interval.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys
from typing import Dict, List, Optional, Sequence, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def ci95(values: Sequence[float]) -> Tuple[float, Optional[float]]:
    """Mean and normal-approximation 95% half-width of ``values``.

    >>> mean, half = ci95([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0])
    >>> round(mean, 3), round(half, 3)
    (5.0, 1.482)
    >>> ci95([3.5])
    (3.5, None)
    """
    vals = [float(v) for v in values]
    n = len(vals)
    mean = sum(vals) / n
    if n < 2:
        return mean, None
    var = sum((v - mean) ** 2 for v in vals) / (n - 1)
    return mean, 1.96 * math.sqrt(var / n)


def _run_one_seed(seed: int, only: str, smoke: bool,
                  backend: str = "") -> List[dict]:
    cmd = [sys.executable, "-m", "benchmarks.run", "--json",
           "--seed", str(seed)]
    if only:
        cmd += ["--only", only]
    if smoke:
        cmd += ["--smoke"]
    if backend:
        cmd += ["--backend", backend]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), REPO,
                    env.get("PYTHONPATH", "")) if p)
    out = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                         text=True)
    if out.returncode != 0:
        raise RuntimeError(f"seed {seed} run failed:\n{out.stderr}")
    return json.loads(out.stdout)["rows"]


def aggregate(per_seed_rows: List[List[dict]]) -> List[dict]:
    """Merge per-seed row lists into one list with ``ci95`` filled in.

    Row order follows the first seed; ``*/wall`` rows are dropped; a
    metric missing from some seed or non-finite on any seed keeps the
    first seed's value with ``ci95: null``.
    """
    series: Dict[str, List[float]] = {}
    for rows in per_seed_rows:
        for r in rows:
            if r["name"].endswith("/wall"):
                continue
            series.setdefault(r["name"], []).append(r["value"])
    out = []
    n_seeds = len(per_seed_rows)
    for r in per_seed_rows[0]:
        name = r["name"]
        if name.endswith("/wall"):
            continue
        vals = series[name]
        finite = all(math.isfinite(v) for v in vals)
        if finite and len(vals) == n_seeds:
            mean, half = ci95(vals)
            out.append({"name": name, "value": mean,
                        "derived": r["derived"],
                        "ci95": None if half is None else [mean, half]})
        else:
            out.append({"name": name, "value": r["value"],
                        "derived": r["derived"], "ci95": None})
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="",
                    help="run only figures whose name contains this")
    ap.add_argument("--seeds", type=int, default=8,
                    help="number of independent seeds (>= 8 for the "
                         "committed figure JSONs)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized fast path for every figure")
    ap.add_argument("--jobs", type=int, default=1,
                    help="seed subprocesses to run concurrently")
    ap.add_argument("--backend", default="",
                    choices=("", "segmented", "pallas", "dense"),
                    help="forwarded to benchmarks.run --backend (Lindley "
                         "solver for sharded sweeps; default unchanged)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the figures/v2 envelope here instead of "
                         "stdout CSV")
    args = ap.parse_args(argv)
    if args.seeds < 1:
        raise SystemExit("--seeds must be >= 1")

    seeds = list(range(args.seeds))
    if args.jobs > 1:
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(max_workers=args.jobs) as pool:
            per_seed = list(pool.map(
                lambda s: _run_one_seed(s, args.only, args.smoke,
                                        args.backend), seeds))
    else:
        per_seed = [_run_one_seed(s, args.only, args.smoke, args.backend)
                    for s in seeds]

    rows = aggregate(per_seed)
    envelope = {"schema": "figures/v2", "seeds": args.seeds,
                "smoke": bool(args.smoke), "rows": rows}
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(envelope, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.json} ({len(rows)} rows, "
              f"{args.seeds} seeds)")
    else:
        print("name,mean,ci95_halfwidth,derived")
        for r in rows:
            half = "" if r["ci95"] is None else f"{r['ci95'][1]:.6g}"
            print(f"{r['name']},{r['value']:.6g},{half},{r['derived']}")


if __name__ == "__main__":
    main()
