"""One function per paper table/figure.  Each returns rows of
(name, value, derived) and is invoked by benchmarks.run.

``SMOKE`` (set by ``benchmarks.run --smoke``) shrinks the expensive
simulation figures (fig12, fig18, fig20, fig21, fig22, fig23, fig24) to
a CI-sized fast path with the same structure and acceptance ratios.
``SEED`` (set by ``benchmarks.run --seed``) is the simulation seed every
figure draws from, so ``benchmarks.montecarlo`` can fan one figure
config across many seeds and report ``mean +/- 95% CI``.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.arrivals import BurstyOnOff, DiurnalProcess, make_arrivals
from repro.core.autoscale import (EWMAPolicy, ReactivePolicy, StaticPolicy,
                                  evaluate_policy)
from repro.core.cost import cost_efficiency_vs_baseline
from repro.core.dsa import DSAConfig
from repro.core.dse import (evaluate, optimal_design, optimal_square_design,
                            pareto, sweep)
from repro.core.energy import energy_reduction_vs_baseline
from repro.core.function import standard_pipeline
from repro.core.latency import LatencyModel
from repro.core.platforms import PLATFORMS
from repro.core.scheduler import (Backpressure, Brownout, ClusterSim,
                                  ExponentialBackoff, FaultPlan, FixedRetry,
                                  NoRetry, OverloadControl, RepairModel,
                                  ShedPolicy, TokenBucket)
from repro.core.tenancy import (SpatialPartition, TenantSpec,
                                WeightedTimeSlice, isolation_violation_rate,
                                jain_index, tenant_reports)
from repro.core.tiering import MigrationPolicy, TierConfig
from repro.core.workloads import WORKLOADS

Row = Tuple[str, float, str]
_LM = LatencyModel()
SMOKE = False                           # benchmarks.run --smoke sets True
SEED = 0                                # benchmarks.run --seed rebinds; every
                                        # simulation figure draws from it so
                                        # montecarlo can fan one config across
                                        # many seeds
BACKEND = "segmented"                   # benchmarks.run --backend rebinds:
                                        # Lindley solver for any sharded
                                        # figure sweep (repro.core.lindley;
                                        # all backends bit-identical)


def _ratio(num: float, den: float) -> float:
    """Ratio rows under arbitrary seeds: a short smoke window can leave a
    bursty tenant with zero requests, so a 0 denominator means "nothing
    to compare against" (inf when the numerator is real, 1.0 when both
    sides are empty) rather than a crash."""
    if den:
        return num / den
    return float("inf") if num else 1.0


def fig04_breakdown() -> List[Row]:
    """Runtime breakdown on the CPU baseline: comm share > 55% average."""
    rows = []
    comms = []
    for name, wl in WORKLOADS.items():
        bd = _LM.pipeline_breakdown(PLATFORMS["Baseline-CPU"], wl)
        comm = (bd["net"] + bd["io"]) / bd["total"]
        comms.append(comm)
        rows.append((f"fig04/{name}/comm_frac", comm,
                     f"total={bd['total'] * 1e3:.1f}ms"))
    rows.append(("fig04/mean_comm_frac", float(np.mean(comms)),
                 "paper: >0.55"))
    return rows


def fig05_tail_cdf() -> List[Row]:
    """S3 read/write tail: p99/p50 ratios (paper: ~2.1x read, ~1.75x write)."""
    wl = WORKLOADS["asset_damage"]
    r50 = _LM.net_read(wl.input_bytes, q=0.50)
    r99 = _LM.net_read(wl.input_bytes, q=0.99)
    w50 = _LM.net_write(wl.output_bytes, q=0.50)
    w99 = _LM.net_write(wl.output_bytes, q=0.99)
    return [("fig05/read_p99_over_p50", r99 / r50, "paper ~2.1"),
            ("fig05/write_p99_over_p50", w99 / w50, "paper ~1.75")]


def fig07_dse_pareto() -> List[Row]:
    pts = sweep()
    best = optimal_design(pts)
    sq = optimal_square_design(pts)
    paper = evaluate(DSAConfig())
    front = pareto([p for p in pts if p.feasible], "power_w")
    big = evaluate(DSAConfig(pe_x=1024, pe_y=1024, scratchpad_bytes=32 << 20,
                             mem_bw=38e9))
    return [
        ("fig07/configs_swept", float(len(pts)), ">650 in paper"),
        ("fig07/square_winner_is_128x128_ddr5",
         float(sq.cfg.pe_x == 128 and sq.cfg.pe_y == 128
               and sq.cfg.mem_bw == 38e9), sq.cfg.name),
        ("fig07/paper_point_power_w", evaluate(DSAConfig()).power_w,
         "paper: 4.2 W"),
        ("fig07/paper_point_fps_frac_of_square_best",
         paper.throughput_fps / sq.throughput_fps, ""),
        ("fig07/1024x1024_feasible", float(big.feasible), "paper: infeasible"),
        ("fig07/beyond_paper_rect_winner_fps", best.throughput_fps,
         f"{best.cfg.name} @ {best.power_w:.1f}W"),
    ]


def _mean_speedup(plat: str, **kw) -> float:
    vals = []
    for wl in WORKLOADS.values():
        base = _LM.e2e(PLATFORMS["Baseline-CPU"], wl, **kw)
        tgt = _LM.e2e(PLATFORMS[plat], wl, **kw)
        vals.append(base / tgt)
    return float(np.mean(vals))


def fig08_speedup() -> List[Row]:
    rows = [(f"fig08/speedup/{p}", _mean_speedup(p), "")
            for p in PLATFORMS if p != "Baseline-CPU"]
    dsa = _mean_speedup("DSCS-Serverless")
    rows += [
        ("fig08/dscs_vs_cpu", dsa, "paper 3.6"),
        ("fig08/dscs_vs_gpu", dsa / _mean_speedup("GPU"), "paper 2.7"),
        ("fig08/dscs_vs_ns_arm", dsa / _mean_speedup("NS-ARM"), "paper 3.7"),
        ("fig08/dscs_vs_ns_fpga", dsa / _mean_speedup("NS-FPGA"), "paper 1.7"),
    ]
    return rows


def fig09_runtime_breakdown() -> List[Row]:
    """Bottleneck shift: on DSCS, compute+comm shrink, stack/f3 dominate."""
    rows = []
    for plat in ("Baseline-CPU", "GPU", "NS-FPGA", "DSCS-Serverless"):
        bd = _LM.pipeline_breakdown(PLATFORMS[plat], WORKLOADS["asset_damage"])
        for k in ("stack", "net", "io", "compute", "driver"):
            rows.append((f"fig09/asset_damage/{plat}/{k}", bd[k] / bd["total"], ""))
    dscs = _LM.pipeline_breakdown(PLATFORMS["DSCS-Serverless"],
                                  WORKLOADS["asset_damage"])
    rows.append(("fig09/dscs_stack_plus_f3net_frac",
                 (dscs["stack"] + dscs["net"]) / dscs["total"],
                 "paper: stack+f3 dominate on DSCS"))
    return rows


def fig10_energy() -> List[Row]:
    rows = []
    means = {}
    for p in PLATFORMS:
        if p == "Baseline-CPU":
            continue
        vals = [energy_reduction_vs_baseline(_LM, wl, p)
                for wl in WORKLOADS.values()]
        means[p] = float(np.mean(vals))
        rows.append((f"fig10/energy_reduction/{p}", means[p], ""))
    rows.append(("fig10/dscs_vs_ns_fpga_energy",
                 means["DSCS-Serverless"] / means["NS-FPGA"], "paper 1.9"))
    return rows


def fig11_cost_efficiency() -> List[Row]:
    rows = []
    means = {}
    for p in ("NS-ARM", "NS-FPGA", "DSCS-Serverless", "GPU"):
        vals = [cost_efficiency_vs_baseline(_LM, wl, p)
                for wl in WORKLOADS.values()]
        means[p] = float(np.mean(vals))
        rows.append((f"fig11/cost_efficiency/{p}", means[p], ""))
    rows.append(("fig11/dscs_vs_ns_arm", means["DSCS-Serverless"] / means["NS-ARM"],
                 "paper 3.2"))
    rows.append(("fig11/dscs_vs_ns_fpga", means["DSCS-Serverless"] / means["NS-FPGA"],
                 "paper 2.3"))
    return rows


def fig12_throughput() -> List[Row]:
    pipes = [standard_pipeline(n) for n in
             ("asset_damage", "content_moderation", "credit_risk")]
    pipes_cpu = [standard_pipeline(n, accelerate=False) for n in
                 ("asset_damage", "content_moderation", "credit_risk")]
    n, dur = (24, 6.0) if SMOKE else (100, 20.0)
    sim = ClusterSim(n_dscs=n, n_cpu=n, seed=SEED)
    sim_cpu = ClusterSim(n_dscs=0, n_cpu=n, seed=SEED)
    dscs = sim.max_throughput(pipes, sla_s=0.6, duration_s=dur)
    cpu = sim_cpu.max_throughput(pipes_cpu, sla_s=0.6, duration_s=dur)
    return [("fig12/dscs_rps", dscs, f"{n} DSCS drives"),
            ("fig12/cpu_rps", cpu, f"{n} CPU nodes"),
            ("fig12/throughput_ratio", dscs / cpu, "paper 3.1")]


def fig13_batch_sensitivity() -> List[Row]:
    rows = []
    for b in (1, 4, 16, 64):
        rows.append((f"fig13/speedup_batch{b}",
                     _mean_speedup("DSCS-Serverless", batch=b),
                     "paper: 3.6 -> 15.9 @64"))
    return rows


def fig14_num_functions() -> List[Row]:
    rows = []
    for extra in (0, 1, 2, 3):
        rows.append((f"fig14/speedup_plus{extra}_funcs",
                     _mean_speedup("DSCS-Serverless", extra_accel_funcs=extra),
                     "paper: 3.6 -> 8.1 @+3"))
    return rows


def fig15_pcie_sensitivity() -> List[Row]:
    rows = []
    base = None
    for lanes in ("gen3x1", "gen3x2", "gen3x4", "gen3x8", "gen3x16", "gen3x32"):
        lm = LatencyModel()
        lm.pcie_lanes = lanes
        vals = [lm.e2e(PLATFORMS["Baseline-CPU"], wl)
                / lm.e2e(PLATFORMS["DSCS-Serverless"], wl)
                for wl in WORKLOADS.values()]
        v = float(np.mean(vals))
        base = base or v
        rows.append((f"fig15/speedup_{lanes}", v / base,
                     "paper: lane count ~no effect (latency-bound)"))
    return rows


def fig16_tail_latency() -> List[Row]:
    rows = []
    for q, label in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
        rows.append((f"fig16/speedup_{label}",
                     _mean_speedup("DSCS-Serverless", q=q),
                     "paper: 3.1 @p50, 5.0 @p99"))
    return rows


def fig17_cold_start() -> List[Row]:
    warm = _mean_speedup("DSCS-Serverless")
    cold = _mean_speedup("DSCS-Serverless", cold=True)
    return [("fig17/speedup_warm", warm, "paper 3.6"),
            ("fig17/speedup_cold", cold, "paper 2.6"),
            ("fig17/cold_lt_warm", float(cold < warm), "must hold")]


def fig18_arrival_scenarios() -> List[Row]:
    """Beyond-paper: throughput-under-SLA sensitivity to the arrival
    process shape (Poisson vs bursty MMPP vs diurnal), same fleet."""
    pipes = [standard_pipeline("content_moderation")]
    rows = []
    base = None
    n, dur = (8, 4.0) if SMOKE else (20, 10.0)
    for kind in ("poisson", "bursty", "diurnal"):
        arr = make_arrivals(kind, 1.0)
        rps = ClusterSim(n_dscs=n, n_cpu=n, seed=SEED).max_throughput(
            pipes, sla_s=0.6, duration_s=dur, hi=2048.0, arrivals=arr)
        base = base or rps
        rows.append((f"fig18/max_rps_{kind}", rps,
                     f"vs_poisson={rps / base:.2f}"))
    return rows


def fig19_hedging_tail() -> List[Row]:
    """Beyond-paper straggler mitigation (Fig. 16 companion): p99 under
    bursty load with hedged dispatch off vs on.  Hedge-on must win."""
    pipes = [standard_pipeline("content_moderation")]
    arr = BurstyOnOff(rate=120.0, burst_factor=5.0, mean_on_s=1.0,
                      mean_off_s=4.0)
    rows = []
    p99 = {}
    for label, budget in (("off", None), ("on", 0.1)):
        sim = ClusterSim(n_dscs=6, n_cpu=24, hedge_budget_s=budget, seed=SEED)
        res = sim.run(pipes, arrivals=arr, duration_s=30)
        lat = np.array([r.latency for r in res])
        p99[label] = float(np.percentile(lat, 99))
        hedged = sum(r.hedged for r in res)
        rows.append((f"fig19/p99_hedge_{label}", p99[label],
                     f"n={len(res)} hedged={hedged}"))
        rows.append((f"fig19/p50_hedge_{label}",
                     float(np.percentile(lat, 50)), ""))
    rows.append(("fig19/p99_hedged_over_unhedged", p99["on"] / p99["off"],
                 "must be < 1"))
    return rows


def fig20_autoscaling() -> List[Row]:
    """Beyond-paper autoscaling sweep (ROADMAP item): static vs reactive
    vs EWMA fleet policies under diurnal and bursty load, scored on cost
    per SLA-met request and energy per request.  The static fleet is
    provisioned for the diurnal peak; the acceptance criterion is that
    both adaptive policies beat it on cost per SLA-met request under the
    diurnal process (the *_vs_static ratios must be < 1)."""
    lm = LatencyModel()
    pipes = [standard_pipeline("asset_damage"),
             standard_pipeline("content_moderation", accelerate=False)]
    n_dscs, n_cpu = 12, 32             # provisioned maxima ~ diurnal peak
    rate, duration, sla = 200.0, (24.0 if SMOKE else 120.0), 0.6
    arrivals = {
        "diurnal": DiurnalProcess(rate=rate, amplitude=0.6, period_s=60.0),
        "bursty": BurstyOnOff(rate=rate, burst_factor=4.0),
    }

    def policies():
        return (("static", StaticPolicy(n_cpu, n_dscs)),
                ("reactive", ReactivePolicy()),
                ("ewma", EWMAPolicy.for_pipelines(lm, pipes)))

    rows = []
    for shape, arr in arrivals.items():
        cost = {}
        sla_frac = {}
        for name, pol in policies():
            rep = evaluate_policy(pol, pipes, arrivals=arr,
                                  duration_s=duration, n_dscs=n_dscs,
                                  n_cpu=n_cpu, sla_s=sla,
                                  hedge_budget_s=0.08, seed=SEED,
                                  latency_model=lm)
            cost[name] = rep.cost_per_sla_req_usd
            sla_frac[name] = rep.sla_frac
            derived = (f"sla={rep.sla_frac:.4f} p99={rep.p99_s:.3f}s "
                       f"cpu={rep.mean_cpu_active:.1f} "
                       f"dscs={rep.mean_dscs_on:.1f} wakes={rep.wake_events}")
            rows.append((f"fig20/{shape}/{name}/cost_per_sla_req_usd",
                         rep.cost_per_sla_req_usd, derived))
            rows.append((f"fig20/{shape}/{name}/energy_per_req_j",
                         rep.energy_per_req_j, ""))
        for name in ("reactive", "ewma"):
            if shape == "diurnal":
                note = "acceptance criterion: must be < 1"
            else:
                # burst-saturated fleet: the ratio compares policies at
                # unequal SLA attainment, so it is context, not a gate
                note = (f"informational: sla {sla_frac[name]:.3f} vs "
                        f"static {sla_frac['static']:.3f}")
            rows.append((f"fig20/{shape}/{name}_vs_static_cost",
                         cost[name] / cost["static"], note))
    return rows


def fig21_tenant_fairness() -> List[Row]:
    """Beyond-paper multi-tenant DSA fairness study (ROADMAP item): a
    latency-sensitive tenant shares the drive fleet with a bursty
    noisy-neighbor tenant, under the three drive schedulers.

    Under FCFS run-to-completion (the paper's §V setting) the neighbor's
    bursts head-of-line-block the latency tenant and blow its p99;
    weighted time-slicing and spatial DSA-lane partitioning restore
    isolation at a quantified throughput cost (context-switch overhead /
    inflated per-request service for the partitioned neighbor).  The
    acceptance criterion is >= 2x p99 improvement for the latency tenant
    under time-slicing vs FCFS (the ``p99_gain`` rows)."""
    dur = 16.0 if SMOKE else 60.0
    pipes = (standard_pipeline("asset_damage"),)
    tenants = [
        TenantSpec("latency", pipes, make_arrivals("poisson", 20.0),
                   sla_s=0.15, weight=1.0),
        TenantSpec("noisy", pipes,
                   BurstyOnOff(rate=45.0, burst_factor=6.0, mean_on_s=2.0,
                               mean_off_s=8.0), sla_s=1.0, weight=1.0),
    ]
    scheds = (("fcfs", None),
              ("timeslice", WeightedTimeSlice(quantum_s=0.01,
                                              switch_s=0.001)),
              ("spatial", SpatialPartition()))

    # solo baseline: the latency tenant alone on the same fleet (FCFS) —
    # what its SLA attainment looks like with no neighbor to collide
    # with.  The neighbor is replaced by a zero-rate ghost (not dropped)
    # so the latency tenant draws from the SAME spawned child stream as
    # the shared runs: the isolation-violation rows then measure pure
    # interference, not arrival-sampling noise.
    ghost = TenantSpec("noisy", pipes, make_arrivals("poisson", 0.0),
                       sla_s=1.0, weight=1.0)
    solo_sim = ClusterSim(n_dscs=4, n_cpu=4, seed=SEED)
    _, solo = solo_sim.run_tenants([tenants[0], ghost], duration_s=dur)
    solo_sla = solo[0].sla_frac

    rows: List[Row] = [("fig21/latency_solo_sla", solo_sla,
                        f"alone on the fleet, dur={dur:g}s")]
    p99 = {}
    for name, sched in scheds:
        sim = ClusterSim(n_dscs=4, n_cpu=4, seed=SEED)
        trace, reps = sim.run_tenants(tenants, duration_s=dur,
                                      scheduler=sched)
        st = sim.tenant_stats()
        for r in reps:
            rows.append((f"fig21/{name}/{r.name}/p99_s", r.p99_s,
                         f"n={r.arrivals} p50={r.p50_s:.3f}s "
                         f"sla={r.sla_frac:.3f}"))
            rows.append((f"fig21/{name}/{r.name}/sla_frac", r.sla_frac,
                         f"sla_s={r.sla_s:g}"))
            p99[(name, r.name)] = r.p99_s
        rows.append((f"fig21/{name}/latency_isolation_violation",
                     isolation_violation_rate(reps[0].sla_frac, solo_sla),
                     "SLA attainment lost to the neighbor"))
        rows.append((f"fig21/{name}/jain_sla", jain_index(
            [r.sla_frac for r in reps]), "fairness of SLA attainment"))
        rows.append((f"fig21/{name}/switch_overhead_s",
                     st["switch_overhead_s"],
                     "DSA context-switch seconds (throughput cost)"))
    for name in ("timeslice", "spatial"):
        rows.append((f"fig21/{name}/latency_p99_gain",
                     _ratio(p99[("fcfs", "latency")],
                            p99[(name, "latency")]),
                     "acceptance criterion: must be >= 2"))
        rows.append((f"fig21/{name}/noisy_p99_cost",
                     _ratio(p99[(name, "noisy")], p99[("fcfs", "noisy")]),
                     "neighbor p99 inflation (the isolation price)"))
    return rows


def fig22_tiered_storage() -> List[Row]:
    """Beyond-paper tiered data layer study (ROADMAP item): p99 and
    throughput vs replication factor x per-drive cache size under
    Zipf-skewed object popularity.

    The paper's static single-replica placement (§V) pins every object on
    one SHA-1-selected drive, so a Zipf-hot key melts that drive while
    the rest of the fleet idles.  The tiered data layer (tiering.py)
    answers with k-way replication (cache-warmth- and load-aware replica
    routing), per-drive DRAM caches (hits skip flash P2P + NS driver),
    lazy backing-store fills and epoch-driven hot-key migration.  The
    acceptance criterion is >= 2x hot-drive p99 improvement for k=2 plus
    a warm cache over the single-replica baseline (the ``p99_gain``
    row, CI-gated by the fig22 smoke step)."""
    dur = 16.0 if SMOKE else 60.0
    rate = 76.0                         # hot drive ~1.0 util at k=1
    n_objects, zipf_s = 256, 1.2        # top object ~25% of traffic
    pipes = [standard_pipeline("asset_damage")]
    arr = make_arrivals("poisson", rate)
    cache_mb = 64

    configs = (
        ("k1", TierConfig(replication_k=1, n_objects=n_objects,
                          zipf_s=zipf_s)),
        ("k2", TierConfig(replication_k=2, n_objects=n_objects,
                          zipf_s=zipf_s)),
        ("k2_cache", TierConfig(replication_k=2,
                                cache_bytes=cache_mb << 20, admit_after=2,
                                n_objects=n_objects, zipf_s=zipf_s)),
        ("k3_cache", TierConfig(replication_k=3,
                                cache_bytes=cache_mb << 20, admit_after=2,
                                n_objects=n_objects, zipf_s=zipf_s)),
        ("k1_migration", TierConfig(replication_k=1, n_objects=n_objects,
                                    zipf_s=zipf_s,
                                    migration=MigrationPolicy(
                                        epoch_s=1.0, max_moves_per_epoch=4,
                                        min_queue_imbalance=4))),
    )

    rows: List[Row] = []
    hot_p99 = {}
    for name, tier in configs:
        sim = ClusterSim(n_dscs=8, n_cpu=8, seed=SEED, tier=tier)
        res = sim.run(pipes, arrivals=arr, duration_s=dur)
        st = sim.tier_stats()
        lat = np.array([r.latency for r in res])
        drv = np.array([r.drive for r in res])
        # hot-drive p99: tail latency of the requests served by the
        # busiest drive — where the Zipf skew lands
        counts = np.bincount(drv[drv >= 0], minlength=8)
        hot = int(np.argmax(counts))
        hot_lat = lat[drv == hot]
        hot_p99[name] = float(np.percentile(hot_lat, 99))
        horizon = max(r.finish for r in res)
        thr = len(res) / horizon
        hit = st["cache"]["hit_rate"]
        mig = st["migration"]
        rows.append((f"fig22/{name}/hot_drive_p99_s", hot_p99[name],
                     f"drive {hot} served {int(counts[hot])}/{len(res)} "
                     f"(hot share {counts[hot] / len(res):.2f})"))
        rows.append((f"fig22/{name}/fleet_p99_s",
                     float(np.percentile(lat, 99)),
                     f"p50={float(np.percentile(lat, 50)):.3f}s"))
        rows.append((f"fig22/{name}/throughput_rps", thr,
                     f"n={len(res)} over {horizon:.1f}s"))
        rows.append((f"fig22/{name}/cache_hit_rate", hit,
                     f"fills={st['backing_fetches']} "
                     f"cache={cache_mb if tier.cache_bytes else 0}MB/drive"))
        if mig is not None:
            rows.append((f"fig22/{name}/migration_moves",
                         float(mig["moves"]),
                         f"over {mig['epochs']} epochs"))
    rows.append(("fig22/k2_cache/p99_gain",
                 hot_p99["k1"] / hot_p99["k2_cache"],
                 "acceptance criterion: must be >= 2"))
    rows.append(("fig22/k1_migration/p99_gain",
                 hot_p99["k1"] / hot_p99["k1_migration"],
                 "hot-key migration alone (informational)"))

    # composition with the fig21 tenant layer: the tier routes replicas
    # under multi-tenant FCFS too (time-slice/spatial DSAs raise)
    tenants = [
        TenantSpec("latency", tuple(pipes), make_arrivals("poisson", 30.0),
                   sla_s=0.3, weight=1.0),
        TenantSpec("batch", tuple(pipes), make_arrivals("poisson", 40.0),
                   sla_s=1.0, weight=1.0),
    ]
    mt_sim = ClusterSim(n_dscs=8, n_cpu=8, seed=SEED,
                        tier=TierConfig(replication_k=2,
                                        cache_bytes=cache_mb << 20,
                                        admit_after=2, n_objects=n_objects,
                                        zipf_s=zipf_s))
    _, reps = mt_sim.run_tenants(tenants, duration_s=dur)
    mt_hit = mt_sim.tier_stats()["cache"]["hit_rate"]
    for r in reps:
        rows.append((f"fig22/tenants_fcfs/{r.name}/p99_s", r.p99_s,
                     f"sla={r.sla_frac:.3f} hit_rate={mt_hit:.3f}"))
    return rows


def fig23_availability() -> List[Row]:
    """Beyond-paper availability study (ISSUE 7): SLA attainment and p99
    vs drive MTBF across retry policies x replication k x repair on/off.

    The paper's fleet assumes 100% availability; real serverless
    platforms are defined by their failure semantics (ServerMix, arXiv
    1907.11465).  This figure runs the fault layer (faults.py) in a
    permanent fail-stop regime — drives die and stay dead for the run,
    plus gray-failure stall windows and a lossy backing store — and
    measures how much of the offered load still meets a tight SLA
    (sla_s below the CPU-fallback path, so a degraded request always
    misses).  Arms at the studied MTBF:

      * ``none_k1``       — the pre-fault-layer engine semantics: single
        replica, lost requests abandoned, no repair (baseline)
      * ``none_k2``       — replica routing alone
      * ``fixed_k2`` / ``expo_k2`` — retry policies on top
      * ``expo_k2_repair`` — the full recovery stack: exponential
        backoff with decorrelated jitter + replica repair re-replicating
        dead drives' objects onto survivors

    The acceptance criterion (CI-gated by the fig23 smoke step) is the
    ``headline/sla_gain`` row: the full stack must hold >= 2x the SLA
    attainment of the no-retry baseline at the studied MTBF."""
    if SMOKE:
        dur, mtbf_studied, mtbf_grid = 16.0, 6.0, (6.0, 12.0)
    else:
        dur, mtbf_studied, mtbf_grid = 40.0, 15.0, (10.0, 15.0, 25.0, 40.0)
    rate, sla_s, timeout_s = 30.0, 0.1, 1.0
    pipes = [standard_pipeline("asset_damage")]

    def plan(retry, repair: bool, mtbf: float) -> FaultPlan:
        return FaultPlan(drive_mtbf_s=mtbf, drive_mttr_s=None,
                         stall_mtbf_s=30.0, stall_s=2.0,
                         backing_fail_p=0.05, retry=retry,
                         repair=(RepairModel(bandwidth_bps=200e6)
                                 if repair else None),
                         detect_timeout_s=0.25)

    cache = {}

    def run(name: str, k: int, retry, repair: bool, mtbf: float):
        key = (name, mtbf)
        if key not in cache:
            tier = TierConfig(replication_k=k, n_objects=256, zipf_s=1.2)
            sim = ClusterSim(n_dscs=8, n_cpu=8, seed=SEED, tier=tier,
                             faults=plan(retry, repair, mtbf))
            tr = sim.engine.run_soa(pipes,
                                    arrivals=make_arrivals("poisson", rate),
                                    duration_s=dur, timeout_s=timeout_s)
            lat = tr.latency
            comp = lat[~np.isnan(lat)]
            fs = sim.fault_stats()
            cache[key] = {
                "sla": float(np.count_nonzero(comp <= sla_s)) / tr.n,
                "p99": (float(np.percentile(comp, 99)) if comp.size
                        else float("inf")),
                "goodput": fs["goodput"]["goodput_frac"],
                "abandoned": fs["abandoned"] + fs["deadline_abandoned"],
                "fails": fs["injected"]["drive_fail"],
                "repair_mb": fs["repair"]["bytes"] / 1e6,
            }
        return cache[key]

    arms = (
        ("none_k1", 1, NoRetry(), False),
        ("none_k2", 2, NoRetry(), False),
        ("fixed_k2", 2, FixedRetry(), False),
        ("expo_k2", 2, ExponentialBackoff(), False),
        ("expo_k2_repair", 2, ExponentialBackoff(), True),
    )

    rows: List[Row] = []
    # availability curve: baseline vs full recovery stack across MTBF
    for mtbf in mtbf_grid:
        for name, k, retry, repair in (arms[0], arms[-1]):
            st = run(name, k, retry, repair, mtbf)
            rows.append((f"fig23/mtbf_{mtbf:g}s/{name}/sla_frac", st["sla"],
                         f"p99={st['p99']:.3f}s fails={st['fails']}"))
    # the full policy grid at the studied MTBF
    for name, k, retry, repair in arms:
        st = run(name, k, retry, repair, mtbf_studied)
        rows.append((f"fig23/{name}/sla_frac", st["sla"],
                     f"mtbf={mtbf_studied:g}s sla={sla_s}s"))
        rows.append((f"fig23/{name}/p99_s", st["p99"],
                     f"completed only; abandoned={st['abandoned']}"))
        rows.append((f"fig23/{name}/goodput_frac", st["goodput"],
                     f"repair_mb={st['repair_mb']:.1f}"))
    base = run("none_k1", 1, NoRetry(), False, mtbf_studied)
    best = run("expo_k2_repair", 2, ExponentialBackoff(), True, mtbf_studied)
    rows.append(("fig23/headline/sla_gain", best["sla"] / base["sla"],
                 "expo backoff + k=2 + repair over no-retry baseline; "
                 "acceptance criterion: must be >= 2"))
    return rows


def fig24_overload() -> List[Row]:
    """Beyond-paper overload study (ISSUE 10): goodput and SLA attainment
    vs offered load at 1x-3x the saturation knee, naive vs protected.

    Goodput here is the overload-control literature's definition — the
    fraction of *offered* load answered within the SLA; a response that
    limps in after the SLA (but before the client timeout) is wasted
    work.  The fleet so far admits every arrival into unbounded FCFS
    queues, so past the saturation knee every request queues for most of
    its deadline and almost nothing finishes inside the SLA — the
    metastable congestion collapse real serverless platforms prevent
    with concurrency limits and throttling (arXiv 2501.09831).
    ``ExponentialBackoff`` retries on injected drive faults and hedged
    duplicates feed the storm.  Arms at each offered load:

      * ``naive``     — PR-6 fleet: faults + unbudgeted exponential-backoff
        retries + hedging, no overload control (baseline)
      * ``protected`` — the same fleet behind the overload layer: token
        bucket at 0.9x the knee, short bounded queues with
        deadline-hopeless shedding, backpressure to the arrival source,
        and brownout (hedging suspended under sustained overload)

    The saturation knee is the offered rate where the clean fleet's
    *median* latency crosses the SLA — the classic knee of the
    latency-throughput curve, found by ``max_throughput`` with
    ``sla_frac=0.5``.  The acceptance criterion (CI-gated by the fig24
    smoke step) is the ``headline/goodput_retention`` row: at 1.5x the
    knee the protected fleet must retain >= 2x the goodput of the naive
    one (measured margin is ~6x; see docs/ARCHITECTURE.md)."""
    if SMOKE:
        dur, knee_dur, mults = 12.0, 8.0, (1.0, 1.5, 2.0)
    else:
        dur, knee_dur, mults = 40.0, 20.0, (1.0, 1.5, 2.0, 3.0)
    n_srv, sla_s, timeout_s = 4, 0.15, 0.5
    pipes = [standard_pipeline("asset_damage")]

    # saturation knee of the clean fleet (no faults, no overload)
    knee = ClusterSim(n_dscs=n_srv, n_cpu=n_srv, seed=SEED).max_throughput(
        pipes, sla_s=sla_s, sla_frac=0.5, duration_s=knee_dur, hi=4096.0)

    def plan() -> FaultPlan:
        return FaultPlan(drive_mtbf_s=20.0, drive_mttr_s=4.0,
                         retry=ExponentialBackoff(base_s=0.01, cap_s=0.5,
                                                  max_attempts=8),
                         retry_budget=None, detect_timeout_s=0.2)

    def protection() -> OverloadControl:
        return OverloadControl(
            admission=TokenBucket(rate=0.9 * knee, burst=8.0),
            shed=ShedPolicy(max_queue=3, hopeless=True),
            backpressure=Backpressure(target_depth=1.0),
            brownout=Brownout(on_depth=1.2, off_depth=0.4))

    cache = {}

    def run(arm: str, mult: float):
        key = (arm, mult)
        if key not in cache:
            sim = ClusterSim(n_dscs=n_srv, n_cpu=n_srv, seed=SEED,
                             hedge_budget_s=0.05, faults=plan(),
                             overload=(protection() if arm == "protected"
                                       else None))
            tr = sim.run(pipes, arrivals=make_arrivals("poisson",
                                                       mult * knee),
                         duration_s=dur, timeout_s=timeout_s)
            lat = np.array([r.latency for r in tr], dtype=float)
            comp = lat[~np.isnan(lat)]
            fs = sim.fault_stats()
            cache[key] = {
                "goodput": (float(np.count_nonzero(comp <= sla_s)) / len(tr)
                            if tr else 0.0),
                "completed": fs["goodput"]["goodput_frac"],
                "rejected": fs["rejected"], "shed": fs["shed"],
                "dead": fs["deadline_abandoned"],
                "ov": sim.overload_stats(),
            }
        return cache[key]

    rows: List[Row] = []
    for mult in mults:
        for arm in ("naive", "protected"):
            st = run(arm, mult)
            rows.append((f"fig24/load_{mult:g}x/{arm}/goodput_frac",
                         st["goodput"],
                         f"sla={sla_s}s knee={knee:.1f}rps "
                         f"rejected={st['rejected']} shed={st['shed']}"))
            rows.append((f"fig24/load_{mult:g}x/{arm}/completed_frac",
                         st["completed"],
                         f"finished before the {timeout_s}s client "
                         f"timeout; deadline_abandoned={st['dead']}"))
    ov = run("protected", 1.5)["ov"]
    pb = min((f for _, f in ov["pushback"]["timeline"]),
             default=ov["pushback"]["final"])
    rows.append(("fig24/load_1.5x/protected/retries_denied",
                 float(ov["retries_denied"]),
                 "retry path consults admission state"))
    rows.append(("fig24/load_1.5x/protected/hedges_suppressed",
                 float(ov["hedges_suppressed"]),
                 f"brownout_entered={ov['brownout']['entered']}"))
    rows.append(("fig24/load_1.5x/protected/pushback_min", pb,
                 "deepest client-side throttle factor over the run"))
    naive = run("naive", 1.5)
    prot = run("protected", 1.5)
    rows.append(("fig24/headline/goodput_retention",
                 _ratio(prot["goodput"], naive["goodput"]),
                 "admission + shedding + brownout over naive fleet at "
                 "1.5x knee; acceptance criterion: must be >= 2"))
    return rows


ALL_FIGURES = [
    fig04_breakdown, fig05_tail_cdf, fig07_dse_pareto, fig08_speedup,
    fig09_runtime_breakdown, fig10_energy, fig11_cost_efficiency,
    fig12_throughput, fig13_batch_sensitivity, fig14_num_functions,
    fig15_pcie_sensitivity, fig16_tail_latency, fig17_cold_start,
    fig18_arrival_scenarios, fig19_hedging_tail, fig20_autoscaling,
    fig21_tenant_fairness, fig22_tiered_storage, fig23_availability,
    fig24_overload,
]
